//! The PIMDB embedding API: an owned, shareable database-service handle.
//!
//! The paper's host programming model treats PIM as a long-lived database
//! service: the PIM copy is constructed once, then many independent
//! queries execute against it (§4). This module is that model as a
//! library surface:
//!
//! * [`Pimdb::open`] takes *ownership* of a [`SystemConfig`] and a
//!   generated [`Database`], lays the relations out over the PIM modules,
//!   and returns a handle that is `Send + Sync` — wrap it in an
//!   [`std::sync::Arc`] and share it across threads.
//! * [`Pimdb::prepare`] turns a [`QuerySource`] (PQL text, an AST
//!   [`Query`], or a TPC-H query name) into a [`Prepared`] statement:
//!   parse → compile → optimize runs **once**, and the compiled plan is
//!   stored in a plan cache keyed by a canonical AST hash
//!   ([`cache::plan_key`]) so re-preparing the same query template —
//!   reformatted, renamed, or re-aliased — is a cache hit. Hit/miss
//!   counters surface in [`QueryMetrics::plan_cache`].
//! * [`Prepared::execute`] runs the plan over the handle's always-on
//!   shard executor from `&self`, against an immutable *snapshot* of
//!   every touched relation. Results come back as a [`QueryResult`]
//!   whose [`Rows`] cursor *decodes* the schema encodings — dates,
//!   money cents, dictionary strings — instead of exposing raw engine
//!   outputs.
//!
//! # Concurrency model: epoch snapshots, group-committed DML
//!
//! Each relation's resident crossbar arrays are published as an
//! immutable, epoch-tagged version behind an `Arc`. The two paths:
//!
//! * **Readers never block on DML.** A query pins the current version of
//!   each relation it touches (one `Arc` clone under a briefly-held
//!   lock) and executes against it on the shared always-on shard pool
//!   ([`crate::exec::pool`]) for as long as it likes. A DML batch
//!   committing mid-query is invisible: the published pointer moves, the
//!   pinned snapshot does not. Every filter ANDs the snapshot's VALID
//!   column, and dead rows are all-zero in that snapshot, so the
//!   optimizer's valid-AND elision stays sound per version.
//! * **Writers group-commit.** DML statements on one relation enqueue
//!   and race for the relation's commit gate; the winner drains the
//!   queue and applies it as one batch against a *private clone* of the
//!   pinned version — no facade lock held while the batch executes, so
//!   concurrent readers keep snapshotting and scanning. On success the
//!   batch commits the epoch-versioned row map
//!   ([`EpochRowMap`] — the two-plane liveness scheme that flips all
//!   per-row visibility bits atomically) and publishes the new version;
//!   on any statement failure the whole batch aborts and the published
//!   version is untouched. Statements on *different* relations never
//!   contend.
//!
//! Shared-scan masks are epoch-tagged: a cached filter-prefix mask
//! replays only for a reader pinned to the exact epoch it was computed
//! against, so DML can never leak deleted rows into (or hide committed
//! rows from) a concurrent reader through the cache.
//!
//! Every fallible path returns the crate-wide typed
//! [`PimdbError`](crate::error::PimdbError).
//!
//! ```
//! use pimdb::api::Pimdb;
//! use pimdb::config::SystemConfig;
//! use pimdb::db::dbgen::Database;
//!
//! let db = Pimdb::open(SystemConfig::default(), Database::generate(0.001, 42))?;
//! let q6 = db.prepare(
//!     "from lineitem
//!      | filter (l_shipdate >= date(1994-01-01) and l_shipdate < date(1995-01-01))
//!          and l_discount between 0.05..0.07 and l_quantity < 24
//!      | aggregate sum(l_extendedprice * l_discount) as revenue_x100",
//! )?;
//! let result = q6.execute()?;
//! for row in result.rows() {
//!     println!("revenue = {}", row.get("revenue_x100").unwrap());
//! }
//! // preparing the same template again (any formatting) hits the cache
//! let again = db.prepare("from lineitem | filter (l_shipdate >= date(1994-01-01)
//!      and l_shipdate < date(1995-01-01)) and l_discount between 0.05..0.07
//!      and l_quantity < 24 | aggregate sum(l_extendedprice*l_discount) as rev")?;
//! assert_eq!(db.plan_cache_counters().hits, 1);
//! # let _ = again;
//! # Ok::<(), pimdb::error::PimdbError>(())
//! ```

pub mod cache;
pub mod rows;

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::config::{DurabilityConfig, SystemConfig};
use crate::db::dbgen::Database;
use crate::db::freerows::{EpochRowMap, FreeRowMap};
use crate::db::layout::DbLayout;
use crate::db::schema::{RelId, PIM_RELATIONS};
use crate::db::stats::RelStats;
use crate::error::PimdbError;
use crate::exec::engine::{self, XbarState};
use crate::exec::metrics::{PlanCacheCounters, QueryMetrics, RunReport, SharedScanCounters};
use crate::exec::pimdb as session;
use crate::exec::plan::ExecPlan;
use crate::exec::pool::ShardPool;
use crate::exec::ExecError;
use crate::query::ast::{Dml, Query};
use crate::query::compiler::{compile_dml, CompileError, CompiledRelQuery, Compiler};
use crate::query::lang;
use crate::query::opt::{self, fusion, prune, sharedscan, OptStats};
use crate::query::tpch;
use crate::storage::recover;
use crate::storage::snapshot::{self, CkptRel, CkptRelSnapshot};
use crate::storage::wal::{self, WalRecord, WalWriter};
use crate::storage::Durability;
use crate::util::bits::{WORDS, XBAR_ROWS};

use cache::{CachedDmlPlan, CachedPlan, PlanCache};

pub use crate::exec::metrics::DmlResult;
pub use crate::storage::DurabilityStats;
pub use crate::exec::pimdb::EngineKind;
pub use rows::{Row, Rows, Value};

/// Where a query to [`Pimdb::prepare`] comes from.
#[derive(Clone, Copy, Debug)]
pub enum QuerySource<'a> {
    /// PQL text (see the grammar in [`crate::query::lang`]).
    Pql(&'a str),
    /// An already-built AST query (cloned into the prepared statement).
    Ast(&'a Query),
    /// One of the 19 evaluated TPC-H queries by name (e.g. `"Q6"`).
    Tpch(&'a str),
}

impl<'a> From<&'a str> for QuerySource<'a> {
    /// Bare strings are PQL text.
    fn from(s: &'a str) -> QuerySource<'a> {
        QuerySource::Pql(s)
    }
}

impl<'a> From<&'a Query> for QuerySource<'a> {
    fn from(q: &'a Query) -> QuerySource<'a> {
        QuerySource::Ast(q)
    }
}

/// Where a DML statement to [`Pimdb::execute_dml`] comes from.
#[derive(Clone, Copy, Debug)]
pub enum DmlSource<'a> {
    /// PQL DML text (`insert into ...` / `update ... set ...` /
    /// `delete from ...`).
    Pql(&'a str),
    /// An already-built AST statement (cloned into the prepared form).
    Ast(&'a Dml),
}

impl<'a> From<&'a str> for DmlSource<'a> {
    /// Bare strings are PQL DML text.
    fn from(s: &'a str) -> DmlSource<'a> {
        DmlSource::Pql(s)
    }
}

impl<'a> From<&'a Dml> for DmlSource<'a> {
    fn from(d: &'a Dml) -> DmlSource<'a> {
        DmlSource::Ast(d)
    }
}

/// One immutable published version of a relation's crossbar arrays.
/// Readers pin a version with an `Arc` clone and execute against it for
/// as long as they like; nothing ever mutates a published version — a
/// committing DML batch swaps in a *new* one. `epoch` counts committed
/// batches (in lockstep with [`EpochRowMap::epoch`]) and tags cached
/// shared-scan masks.
struct RelVersion {
    epoch: u64,
    states: Arc<Vec<XbarState>>,
    /// Zone-map statistics of exactly these planes
    /// ([`RelStats`]), published in lockstep with them so a pinned
    /// snapshot reader always prunes against stats that describe the
    /// crossbars it is scanning — never a newer or older version's.
    stats: Arc<RelStats>,
}

/// Liveness and wear bookkeeping of one relation. `rows` stays `None`
/// until the first DML batch touches the relation — wear accounting
/// starts with the first mutation, exactly like the pre-snapshot facade.
struct RelBook {
    /// Epoch-versioned liveness + monotone per-row wear.
    rows: Option<EpochRowMap>,
    /// Reader-side wear accumulator, one slot per crossbar row: snapshot
    /// readers fold their programs' write profiles here (a brief lock,
    /// never waiting on an executing batch), and the next DML batch
    /// charges the ledger into the committed map *before* its allocator
    /// looks at row heat — so allocation decisions match the legacy
    /// charge-immediately facade for any serial interleaving.
    ledger: Vec<u64>,
}

/// One submitted DML statement's result slot, filled by whichever
/// thread leads the batch that executes (or aborts) it.
struct DmlSlot {
    done: Mutex<Option<Result<DmlResult, PimdbError>>>,
}

/// A DML request waiting for the next group-commit batch.
struct DmlRequest {
    plan: Arc<CachedDmlPlan>,
    engine_kind: EngineKind,
    slot: Arc<DmlSlot>,
    /// Canonical AST bytes ([`cache::dml_bytes`]) for the batch's WAL
    /// record; populated only on durable handles.
    bytes: Option<Vec<u8>>,
}

/// Per-relation concurrency structure. Every lock is held briefly
/// (pointer swaps and bit bookkeeping), except `gate`, which serializes
/// *writers only* for the duration of a batch — readers never take it.
struct RelSlot {
    /// Latest published version (`None` until first materialization).
    published: Mutex<Option<Arc<RelVersion>>>,
    /// Lock-free mirror of the published epoch. Poison recovery reads it
    /// to raise the scan-cache floor without nesting lock acquisitions.
    epoch_hint: AtomicU64,
    /// Liveness + wear bookkeeping.
    book: Mutex<RelBook>,
    /// Group-commit gate: writers enqueue on `queue`, then race for this
    /// lock; the winner drains the queue and applies it as one batch.
    gate: Mutex<()>,
    /// Requests awaiting the next batch.
    queue: Mutex<Vec<DmlRequest>>,
    /// Epoch-tagged shared-scan masks.
    scans: Mutex<ScanMaskCache>,
}

/// Bound on cached scan masks per relation: a serving workload with
/// per-request literals mints unbounded distinct prefixes; past the cap
/// the oldest entry is evicted (FIFO — prefix reuse in a prepared
/// workload is dominated by a handful of hot scans).
const MAX_CACHED_SCANS: usize = 8;

/// A cached filter-prefix mask: one plane per crossbar, shared by `Arc`
/// so a reader can keep replaying it after the entry is evicted.
type CachedMask = Arc<Vec<[u64; WORDS]>>;

/// Per-relation store of executed filter-prefix masks, keyed by the
/// canonical prefix bytes of [`sharedscan::ScanInfo`] *and* the epoch of
/// the version they were computed against. Byte equality of keys implies
/// the identical mask function; epoch equality implies identical input
/// data — together, replaying a cached mask is exact, not approximate,
/// even while DML batches republish the relation concurrently.
///
/// `epoch_floor` is the poison-recovery rule: after a panic under the
/// cache lock, everything resident is dropped **and** the floor rises
/// past the current epoch, so even a mask computed concurrently with the
/// panic (still in flight, inserted later) can never be admitted. The
/// cache stays cold until a DML commit moves the relation to an epoch at
/// or above the floor.
/// A cached skip bitmap: which crossbars the zone maps proved all-zero
/// for the mask function, at the epoch the mask was computed. A
/// transplanted shared mask always carries its skip bitmap — the pair
/// describes the same version, so any member sharing the key at that
/// epoch prunes identically to the run that populated the entry.
type CachedSkip = Arc<Vec<bool>>;

struct ScanMaskCache {
    entries: Vec<(Vec<u8>, u64, CachedMask, CachedSkip)>,
    epoch_floor: u64,
}

impl ScanMaskCache {
    fn new() -> ScanMaskCache {
        ScanMaskCache {
            entries: Vec::new(),
            epoch_floor: 0,
        }
    }

    /// The mask (and its skip bitmap) for `key` computed at exactly
    /// `epoch`, if admitted.
    fn get(&self, key: &[u8], epoch: u64) -> Option<(CachedMask, CachedSkip)> {
        if epoch < self.epoch_floor {
            return None;
        }
        self.entries
            .iter()
            .find(|(k, e, _, _)| *e == epoch && k == key)
            .map(|(_, _, m, s)| (Arc::clone(m), Arc::clone(s)))
    }

    fn insert(&mut self, key: Vec<u8>, epoch: u64, mask: CachedMask, skip: CachedSkip) {
        if epoch < self.epoch_floor {
            return;
        }
        if let Some(e) = self.entries.iter_mut().find(|(k, _, _, _)| *k == key) {
            *e = (key, epoch, mask, skip);
            return;
        }
        if self.entries.len() >= MAX_CACHED_SCANS {
            self.entries.remove(0);
        }
        self.entries.push((key, epoch, mask, skip));
    }

    /// Drop masks older than `epoch` (a newly published version makes
    /// them unreplayable); `true` when anything was dropped.
    fn purge_below(&mut self, epoch: u64) -> bool {
        let before = self.entries.len();
        self.entries.retain(|(_, e, _, _)| *e >= epoch);
        self.entries.len() != before
    }

    /// Poison recovery: drop everything and raise the floor past
    /// `current_epoch`; `true` when anything was dropped.
    fn poison_bump(&mut self, current_epoch: u64) -> bool {
        self.epoch_floor = self.epoch_floor.max(current_epoch + 1);
        let had = !self.entries.is_empty();
        self.entries.clear();
        had
    }
}

/// Handle-wide shared-scan counters (atomic: executions run from
/// `&self` across threads).
#[derive(Default)]
struct ScanStats {
    hits: AtomicU64,
    misses: AtomicU64,
    invalidations: AtomicU64,
}

/// Lock a facade mutex whose contents are consistent by construction
/// (request queues, result slots, the group-commit gate): poisoning only
/// means some *other* thread panicked while holding it.
fn lock_plain<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            m.clear_poison();
            poisoned.into_inner()
        }
    }
}

/// The owned PIMDB service handle: one resident database copy, a plan
/// cache, an always-on shard executor, and per-relation published
/// snapshots so prepared queries execute concurrently from `&self` (see
/// the module docs).
///
/// Since the snapshot refactor the handle serves reads and writes
/// concurrently: [`Pimdb::execute_dml`] applies `insert into` /
/// `update ... set` / `delete from` statements through per-relation
/// group-commit batches — valid-bit liveness, endurance-aware free-row
/// allocation, wear accounting — while queries keep executing against
/// their pinned pre-batch snapshots, never waiting on an in-flight
/// batch. Every filter ANDs the VALID column of its snapshot, so a
/// query observes exactly one committed state: pre- or post-batch,
/// never a torn one.
pub struct Pimdb {
    cfg: SystemConfig,
    db: Database,
    layout: DbLayout,
    exec_plan: ExecPlan,
    fingerprint: u64,
    /// Per-relation snapshot/commit machinery. Statements on disjoint
    /// relations proceed fully in parallel; writers sharing a relation
    /// group-commit; readers never serialize with anything.
    rels: BTreeMap<RelId, RelSlot>,
    /// The always-on shard executor every reader submits to.
    pool: ShardPool,
    cache: PlanCache,
    scan_stats: ScanStats,
    /// Write-ahead log + checkpoint machinery; `None` on in-memory
    /// handles ([`Pimdb::open`]).
    durability: Option<Durability>,
}

// The service-handle contract: `Pimdb` (and everything borrowed from it)
// must stay shareable across threads. Compile-time regression guard for
// the old `PimSession<'a>`-style borrow/`&mut` coupling.
const fn assert_send_sync<T: Send + Sync>() {}
const _: () = {
    assert_send_sync::<Pimdb>();
    assert_send_sync::<Prepared<'static>>();
    assert_send_sync::<PreparedDml<'static>>();
    assert_send_sync::<QueryResult>();
};

impl Pimdb {
    /// Take ownership of a configuration and database, lay the relations
    /// out over the PIM modules, spin up the always-on shard executor
    /// ([`SystemConfig::parallelism`] workers under the
    /// [`SystemConfig::admission`] cap) and return the service handle.
    /// Crossbar states materialize lazily, per relation, on first
    /// execution.
    pub fn open(cfg: SystemConfig, db: Database) -> Result<Pimdb, PimdbError> {
        Pimdb::open_with(cfg, db, None)
    }

    /// Open a *durable* handle rooted at `dcfg.data_dir`: initialize the
    /// directory on first use (dbgen at `dcfg.seed`, a base image, an
    /// empty generation-0 checkpoint and WAL segment), or recover it —
    /// load the newest digest-valid checkpoint, truncate a torn WAL tail
    /// at the last record boundary, and replay the logged epoch suffix
    /// through the normal DML execution path. After recovery the handle
    /// is bit-identical to one that never closed: same crossbar planes,
    /// same liveness, same committed wear, same epochs.
    ///
    /// Every subsequent committed DML batch appends one WAL record
    /// *before* publishing (honouring [`DurabilityConfig::fsync`]);
    /// [`Pimdb::checkpoint`] bounds replay work and
    /// [`Pimdb::durability_stats`] reports what the layer has done.
    ///
    /// Corrupt on-disk state (checksum or digest mismatch, mangled
    /// records) is refused with [`PimdbError::Corrupt`]; operating-system
    /// failures surface as [`PimdbError::Io`]; a `sim_sf` mismatch with
    /// the directory's base image is [`PimdbError::Config`].
    ///
    /// ```no_run
    /// use pimdb::api::Pimdb;
    /// use pimdb::config::{DurabilityConfig, SystemConfig};
    ///
    /// let dcfg = DurabilityConfig::new("/var/lib/pimdb");
    /// let db = Pimdb::open_durable(SystemConfig::default(), dcfg)?;
    /// db.execute_dml("delete from supplier where s_suppkey <= 3")?;
    /// db.checkpoint()?; // bound replay work; fsync already made it durable
    /// # Ok::<(), pimdb::error::PimdbError>(())
    /// ```
    pub fn open_durable(cfg: SystemConfig, dcfg: DurabilityConfig) -> Result<Pimdb, PimdbError> {
        let fingerprint = cache::plan_fingerprint(&cfg);
        let prepared = recover::prepare(&cfg, &dcfg, fingerprint)?;
        let durability = Durability::new(
            dcfg,
            fingerprint,
            prepared.writer,
            prepared.torn_tails,
            prepared.checkpoints_skipped,
            prepared.last_checkpoint_epoch,
        );
        let handle = Pimdb::open_with(cfg, prepared.db, Some(durability))?;
        handle.install_recovered(prepared.ckpt)?;
        handle.replay(prepared.wal_batches)?;
        Ok(handle)
    }

    fn open_with(
        cfg: SystemConfig,
        db: Database,
        durability: Option<Durability>,
    ) -> Result<Pimdb, PimdbError> {
        // An explicit admission cap below the worker count can never
        // admit enough shard jobs to keep the executor busy: workers
        // past the cap idle forever and one reader's shard fan-out
        // trickles through the gate. Reject the misconfiguration with a
        // typed error instead of silently serializing (0 stays the
        // documented `4 * parallelism` auto cap).
        if cfg.admission != 0 && cfg.admission < cfg.parallelism {
            return Err(PimdbError::Config(format!(
                "admission cap {} is below parallelism {}: shard workers past \
                 the cap could never be kept busy (use admission = 0 for the \
                 4 * parallelism auto cap)",
                cfg.admission, cfg.parallelism
            )));
        }
        let layout = DbLayout::build(&cfg, &|r| db.rel(r).records as u64)?;
        let rels = PIM_RELATIONS
            .iter()
            .map(|&r| {
                (
                    r,
                    RelSlot {
                        published: Mutex::new(None),
                        epoch_hint: AtomicU64::new(0),
                        book: Mutex::new(RelBook {
                            rows: None,
                            ledger: vec![0; XBAR_ROWS],
                        }),
                        gate: Mutex::new(()),
                        queue: Mutex::new(Vec::new()),
                        scans: Mutex::new(ScanMaskCache::new()),
                    },
                )
            })
            .collect();
        Ok(Pimdb {
            exec_plan: ExecPlan::for_config(&cfg),
            fingerprint: cache::plan_fingerprint(&cfg),
            pool: ShardPool::new(cfg.parallelism, cfg.admission),
            layout,
            rels,
            cache: PlanCache::new(),
            scan_stats: ScanStats::default(),
            durability,
            cfg,
            db,
        })
    }

    /// Install the checkpointed relation states produced by recovery:
    /// publish each relation's crossbar planes at its checkpointed epoch
    /// and restore its liveness/wear book. Runs before the handle is
    /// shared, but takes the normal locks anyway.
    fn install_recovered(&self, ckpt: Vec<CkptRel>) -> Result<(), PimdbError> {
        for r in ckpt {
            let slot = self.slot(r.rel);
            let epoch = r.epoch;
            let rlayout = self.layout.rel(r.rel);
            {
                let mut book = self.lock_book(slot);
                book.rows = Some(EpochRowMap::restore(
                    FreeRowMap::restore(r.live, r.wear, XBAR_ROWS),
                    epoch,
                ));
                book.ledger = r.ledger;
            }
            // stats are derived state: never checkpointed, always rebuilt
            // from the recovered planes through the normal build path
            let states = Arc::new(r.states);
            let stats = Arc::new(RelStats::build(&states, rlayout));
            *self.lock_published(slot) = Some(Arc::new(RelVersion {
                epoch,
                states,
                stats,
            }));
            slot.epoch_hint.store(epoch, Ordering::Release);
        }
        Ok(())
    }

    /// Replay the WAL suffix produced by recovery. Records at or below a
    /// relation's checkpointed epoch are skipped (already captured); the
    /// suffix must be contiguous — an epoch gap means a lost segment and
    /// refuses the open rather than silently skipping committed batches.
    fn replay(&self, records: Vec<WalRecord>) -> Result<(), PimdbError> {
        let mut replayed = 0u64;
        for record in &records {
            let rel = record.rel()?;
            let current = self.relation_epoch(rel);
            if record.epoch <= current {
                continue;
            }
            if record.epoch != current + 1 {
                return Err(PimdbError::Corrupt(format!(
                    "wal replay: {rel:?} at epoch {current} but the next \
                     record is epoch {} — a log segment is missing",
                    record.epoch
                )));
            }
            self.replay_batch(rel, record)?;
            replayed += 1;
        }
        if let Some(d) = &self.durability {
            d.note_replayed(replayed);
        }
        Ok(())
    }

    /// Re-execute one logged batch: decode and compile its canonical DML
    /// bytes, charge the recorded reader-wear fold profile, run the
    /// statements through the same `exec_dml_on_states` path the live
    /// leader used, and commit. Deterministic because group commit is
    /// serial per relation and the allocator sees the same wear ranking.
    fn replay_batch(&self, rel: RelId, record: &WalRecord) -> Result<(), PimdbError> {
        let mut plans = Vec::with_capacity(record.stmts.len());
        for bytes in &record.stmts {
            let dml = wal::decode_dml(bytes, self.fingerprint)?;
            if dml.rel() != rel {
                return Err(PimdbError::Corrupt(format!(
                    "wal replay: record tagged {rel:?} carries a statement \
                     for {:?}",
                    dml.rel()
                )));
            }
            let plan = self.cache.get_or_compile_dml(bytes.clone(), || {
                Ok(CachedDmlPlan {
                    compiled: compile_dml(&dml, self.layout.rel(rel), self.cfg.xbar_cols)?,
                })
            })?;
            plans.push(plan);
        }

        let slot = self.slot(rel);
        let version = self.snapshot(rel);
        let mut pending = {
            let mut book = self.lock_book(slot);
            let RelBook { rows, ledger } = &mut *book;
            let rows = rows.get_or_insert_with(|| {
                let r = self.db.rel(rel);
                let capacity = version.states.len() * XBAR_ROWS;
                let flags: Vec<bool> = (0..r.records).map(|i| r.live(i)).collect();
                EpochRowMap::new(FreeRowMap::from_flags(&flags, capacity, XBAR_ROWS))
            });
            // The recorded fold profile *is* the ledger content the live
            // batch charged at its begin — replay the charge verbatim and
            // zero the recovered ledger so committed wear (and therefore
            // the allocator's row ranking) matches the live handle
            // bit-for-bit.
            if !record.fold.is_empty() {
                let mut dense = vec![0u64; XBAR_ROWS];
                for &(idx, w) in &record.fold {
                    let Some(d) = dense.get_mut(idx as usize) else {
                        return Err(PimdbError::Corrupt(format!(
                            "wal replay: fold row {idx} is outside the \
                             crossbar ({XBAR_ROWS} rows)"
                        )));
                    };
                    *d = w;
                }
                rows.charge_profile(&dense);
            }
            ledger.fill(0);
            rows.begin_batch()
        };

        let mut states: Vec<XbarState> = (*version.states).clone();
        for plan in &plans {
            session::exec_dml_on_states(
                &self.cfg,
                &self.layout,
                rel,
                &mut states,
                &mut pending,
                &plan.compiled,
                EngineKind::Native,
                &self.exec_plan,
            )
            .map_err(|e| {
                PimdbError::Corrupt(format!(
                    "wal replay: logged batch (epoch {}) failed to \
                     re-execute: {e}",
                    record.epoch
                ))
            })?;
        }

        let mut book = self.lock_book(slot);
        let rows = book.rows.as_mut().expect("created above");
        rows.commit_batch(pending);
        let epoch = rows.epoch();
        drop(book);
        let states = Arc::new(states);
        let stats = Arc::new(RelStats::update(
            &version.stats,
            &version.states,
            &states,
            self.layout.rel(rel),
        ));
        *self.lock_published(slot) = Some(Arc::new(RelVersion {
            epoch,
            states,
            stats,
        }));
        slot.epoch_hint.store(epoch, Ordering::Release);
        debug_assert_eq!(epoch, record.epoch, "commit advances by exactly one");
        Ok(())
    }

    /// Write a checkpoint: quiesce writers (every relation gate, taken in
    /// `RelId` order — readers are unaffected), capture each touched
    /// relation's published planes, liveness/wear and epoch into
    /// generation *g+1*, rotate the WAL to a fresh segment, and prune
    /// generations older than *g* (the previous generation stays on disk
    /// as the corruption fallback). Returns the checkpoint's size in
    /// bytes. [`PimdbError::Config`] on an in-memory handle.
    pub fn checkpoint(&self) -> Result<u64, PimdbError> {
        let d = self.durability.as_ref().ok_or_else(|| {
            PimdbError::Config(
                "checkpoint requires a durable handle (use Pimdb::open_durable)".into(),
            )
        })?;
        // All gates in BTreeMap (RelId) order: writers quiesce, in-flight
        // readers keep scanning their pinned snapshots.
        let _gates: Vec<MutexGuard<'_, ()>> =
            self.rels.values().map(|s| lock_plain(&s.gate)).collect();

        struct Snap {
            rel: RelId,
            epoch: u64,
            states: Arc<Vec<XbarState>>,
            live: Vec<bool>,
            wear: Vec<u64>,
            ledger: Vec<u64>,
        }
        let mut snaps = Vec::new();
        for (&rel, slot) in &self.rels {
            let book = self.lock_book(slot);
            let Some(rows) = book.rows.as_ref() else {
                // untouched by DML: the base image is this relation's
                // durable state, nothing to checkpoint
                continue;
            };
            let committed = rows.committed();
            let capacity = committed.capacity();
            let snap = Snap {
                rel,
                epoch: rows.epoch(),
                states: Arc::new(Vec::new()),
                live: (0..capacity).map(|r| committed.is_live(r)).collect(),
                wear: (0..capacity).map(|r| committed.row_wear(r)).collect(),
                ledger: book.ledger.clone(),
            };
            drop(book);
            let version = self.snapshot(rel);
            debug_assert_eq!(version.epoch, snap.epoch, "writers are quiesced");
            snaps.push(Snap {
                states: Arc::clone(&version.states),
                ..snap
            });
        }
        let views: Vec<CkptRelSnapshot<'_>> = snaps
            .iter()
            .map(|s| CkptRelSnapshot {
                rel: s.rel,
                epoch: s.epoch,
                states: &s.states,
                live: s.live.clone(),
                wear: s.wear.clone(),
                ledger: s.ledger.clone(),
            })
            .collect();

        let generation = d.generation() + 1;
        let dir = d.cfg.data_dir.clone();
        let bytes = snapshot::write_checkpoint(&dir, d.fingerprint, generation, &views)
            .map_err(|e| PimdbError::Io(format!("checkpoint {generation}: {e}")))?;
        let writer = WalWriter::create(&dir, generation, d.fingerprint)
            .map_err(|e| PimdbError::Io(format!("wal segment {generation}: {e}")))?;
        let epoch_hi = snaps.iter().map(|s| s.epoch).max().unwrap_or(0);
        d.rotate(writer, epoch_hi);
        recover::prune_generations(&dir, generation.saturating_sub(1));
        Ok(bytes)
    }

    /// Durability counters of this handle (WAL records/bytes appended,
    /// records replayed and torn tails truncated by the recovery that
    /// produced it, checkpoints written); `None` on in-memory handles.
    pub fn durability_stats(&self) -> Option<DurabilityStats> {
        self.durability.as_ref().map(|d| d.stats())
    }

    /// The configuration the handle was opened with.
    pub fn cfg(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The resident database *load image* (for baselines and oracles).
    /// DML mutates the PIM copy, not this image — hold your own
    /// [`Database`] copy and mirror statements through
    /// [`crate::exec::baseline::apply_dml`] when a host-side twin of the
    /// mutated state is needed (the differential suites do exactly that).
    pub fn database(&self) -> &Database {
        &self.db
    }

    /// Live records currently committed in the PIM copy of `rel` (the
    /// load image's live count until a DML batch touches the relation).
    pub fn live_records(&self, rel: RelId) -> usize {
        let slot = self.slot(rel);
        let book = self.lock_book(slot);
        book.rows
            .as_ref()
            .map(|r| r.live_count())
            .unwrap_or_else(|| self.db.rel(rel).live_count())
    }

    /// Committed DML batches so far on `rel` — the epoch tag the next
    /// reader snapshot pins (0 until the first batch commits).
    pub fn relation_epoch(&self, rel: RelId) -> u64 {
        self.slot(rel).epoch_hint.load(Ordering::Acquire)
    }

    /// Per-row cumulative cell-write counters of `rel` (monotonically
    /// nondecreasing; empty until a DML statement touches the relation —
    /// wear accounting starts with the first mutation). Reported wear is
    /// committed wear plus the reader ledger, so an aborted batch never
    /// moves an observed counter.
    pub fn wear_counters(&self, rel: RelId) -> Vec<u64> {
        let slot = self.slot(rel);
        let book = self.lock_book(slot);
        match book.rows.as_ref() {
            Some(rows) => {
                let committed = rows.committed();
                (0..committed.capacity())
                    .map(|r| committed.row_wear(r).wrapping_add(book.ledger[r % XBAR_ROWS]))
                    .collect()
            }
            None => Vec::new(),
        }
    }

    /// The database's PIM layout (page placement, column slots).
    pub fn layout(&self) -> &DbLayout {
        &self.layout
    }

    /// Plan-cache hit/miss counters so far (also snapshotted into every
    /// execution's [`QueryMetrics::plan_cache`]).
    pub fn plan_cache_counters(&self) -> PlanCacheCounters {
        self.cache.counters()
    }

    /// Shared-scan cache counters so far: executions that replayed a
    /// cached filter-prefix mask (`hits`), shareable executions that ran
    /// in full and populated the cache (`misses`), and per-relation cache
    /// drops (`invalidations` — a DML commit that obsoleted resident
    /// masks, or poison recovery).
    pub fn shared_scan_counters(&self) -> SharedScanCounters {
        SharedScanCounters {
            hits: self.scan_stats.hits.load(Ordering::Relaxed),
            misses: self.scan_stats.misses.load(Ordering::Relaxed),
            invalidations: self.scan_stats.invalidations.load(Ordering::Relaxed),
        }
    }

    /// Drop all cached plans (counters keep accumulating); the next
    /// prepare of any template recompiles. Benchmarks use this to measure
    /// the unprepared path.
    pub fn clear_plan_cache(&self) {
        self.cache.clear()
    }

    /// Render the statistics-driven pruning decisions the handle would
    /// apply to `source` right now: per relation program, the per-shard
    /// skip bitmap derived from the current published version's zone
    /// maps, the zone ranges the decision consulted, the cost-ordered
    /// predicate sequence, and the runtime all-zero short-circuit
    /// schedule. `pimdb run --explain` prints this next to the optimizer
    /// disassembly ([`crate::query::opt::explain_query`]).
    pub fn explain_pruning<'q>(
        &self,
        source: impl Into<QuerySource<'q>>,
    ) -> Result<String, PimdbError> {
        use std::fmt::Write;
        let p = self.prepare(source)?;
        let mut s = String::new();
        for (rq, c) in p.query.rels.iter().zip(&p.plan.compiled) {
            let version = self.snapshot(c.rel);
            writeln!(
                s,
                "-- {}: pruning (epoch {}, {} crossbars) --",
                c.rel.name(),
                version.epoch,
                version.states.len()
            )
            .expect("write to String");
            s.push_str(&prune::explain_pruning(
                &rq.filter,
                self.layout.rel(c.rel),
                &version.stats,
                &c.steps,
                c.mask_col,
                self.cfg.xbar_rows,
            ));
        }
        Ok(s)
    }

    /// Prepare one query: parse (if text), compile and optimize once —
    /// or fetch the plan from the cache — and return the executable
    /// statement. A PQL program with several `query` blocks is an
    /// [`PimdbError::ExpectedSingleQuery`] error; use
    /// [`Pimdb::prepare_all`] for programs.
    pub fn prepare<'q>(
        &self,
        source: impl Into<QuerySource<'q>>,
    ) -> Result<Prepared<'_>, PimdbError> {
        let mut queries = self.resolve(source.into())?;
        if queries.len() != 1 {
            return Err(PimdbError::ExpectedSingleQuery {
                found: queries.len(),
            });
        }
        self.prepare_query(queries.pop().expect("length checked"))
    }

    /// Prepare every query of a source (a PQL program may hold several
    /// `query` blocks), in source order.
    pub fn prepare_all<'q>(
        &self,
        source: impl Into<QuerySource<'q>>,
    ) -> Result<Vec<Prepared<'_>>, PimdbError> {
        self.resolve(source.into())?
            .into_iter()
            .map(|q| self.prepare_query(q))
            .collect()
    }

    fn resolve(&self, source: QuerySource<'_>) -> Result<Vec<Query>, PimdbError> {
        match source {
            QuerySource::Pql(text) => {
                lang::parse_program(text).map_err(|diag| PimdbError::Parse {
                    diag,
                    src: text.to_string(),
                })
            }
            QuerySource::Ast(q) => Ok(vec![q.clone()]),
            QuerySource::Tpch(name) => tpch::query(name)
                .map(|q| vec![q])
                .ok_or_else(|| PimdbError::UnknownQuery(name.to_string())),
        }
    }

    fn prepare_query(&self, query: Query) -> Result<Prepared<'_>, PimdbError> {
        // the cache map keys on the full canonical bytes (collision-free);
        // plan_key is the same stream's compact digest for observability
        let key = cache::plan_bytes(&query, self.cfg.opt_level, self.fingerprint);
        // Zone-map snapshot per touched relation, pinned *before* the
        // compile closure (snapshot takes the published lock; the cache
        // holds its own — never nested). It feeds the cost-based
        // predicate-ordering pass; plan-cache stability then keeps the
        // chosen order fixed for the template's lifetime on this handle,
        // so later DML never silently re-orders a cached plan.
        let stats: BTreeMap<RelId, Arc<RelStats>> = query
            .rels
            .iter()
            .map(|rq| rq.rel)
            .collect::<BTreeSet<RelId>>()
            .into_iter()
            .map(|r| (r, Arc::clone(&self.snapshot(r).stats)))
            .collect();
        let plan = self.cache.get_or_compile(key, || {
            let mut sum = OptStats::default();
            let mut compiled = Vec::with_capacity(query.rels.len());
            let mut sim = Vec::with_capacity(query.rels.len());
            for rq in &query.rels {
                let c = Compiler::compile(rq, self.layout.rel(rq.rel), self.cfg.xbar_cols)?;
                // two pass pipelines over the one compiled stream: the
                // plain one is what the simulator charges (bit-identical
                // to the legacy session), the stats-fed one is what the
                // executor runs (cost-ordered so the runtime all-zero
                // short-circuit fires as early as possible)
                let (plain, st) =
                    opt::optimize(&c, self.cfg.opt_level, self.cfg.xbar_rows);
                let model =
                    prune::SelectivityModel::new(self.layout.rel(rq.rel), &stats[&rq.rel]);
                let (exec, _) = opt::optimize_with_stats(
                    &c,
                    self.cfg.opt_level,
                    self.cfg.xbar_rows,
                    Some(&model),
                );
                sum.merge(&st);
                sim.push(plain);
                compiled.push(exec);
            }
            let scans = compiled.iter().map(sharedscan::scan_info).collect();
            Ok(CachedPlan {
                compiled,
                sim,
                scans,
                opt: sum.into(),
            })
        })?;
        let plan = rebind_labels(plan, &query);
        Ok(Prepared {
            handle: self,
            query,
            plan,
        })
    }

    fn slot(&self, rel: RelId) -> &RelSlot {
        self.rels.get(&rel).expect("PIM relation")
    }

    /// Lock a relation's scan-mask cache, recovering from poisoning with
    /// the epoch-floor bump: nothing resident survives, and nothing
    /// computed against the pre-panic view can be admitted later (see
    /// [`ScanMaskCache`]).
    fn lock_scans<'a>(&self, slot: &'a RelSlot) -> MutexGuard<'a, ScanMaskCache> {
        match slot.scans.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                slot.scans.clear_poison();
                let mut g = poisoned.into_inner();
                if g.poison_bump(slot.epoch_hint.load(Ordering::Acquire)) {
                    self.scan_stats.invalidations.fetch_add(1, Ordering::Relaxed);
                }
                g
            }
        }
    }

    /// Lock a relation's bookkeeping, recovering from poisoning. A panic
    /// under the book lock can only have struck bit bookkeeping: an
    /// in-flight batch is aborted (committed liveness and wear are
    /// untouched by construction — the batch mutates a take-out clone),
    /// the reader ledger is kept (a plain accumulator), and the
    /// scan-cache floor rises so no mask from around the panic is ever
    /// replayed.
    fn lock_book<'a>(&self, slot: &'a RelSlot) -> MutexGuard<'a, RelBook> {
        match slot.book.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                slot.book.clear_poison();
                let mut g = poisoned.into_inner();
                if g.rows.as_ref().is_some_and(|r| r.in_batch()) {
                    g.rows.as_mut().expect("checked above").abort_batch();
                }
                let mut scans = self.lock_scans(slot);
                if scans.poison_bump(slot.epoch_hint.load(Ordering::Acquire)) {
                    self.scan_stats.invalidations.fetch_add(1, Ordering::Relaxed);
                }
                g
            }
        }
    }

    /// Lock a relation's published-version pointer, recovering from
    /// poisoning. The pointer swap itself cannot tear (one `Arc`
    /// assignment under the guard), but a panic between the book commit
    /// and the publish can leave cached masks describing a version that
    /// was about to be superseded — so recovery distrusts the scan cache.
    fn lock_published<'a>(
        &self,
        slot: &'a RelSlot,
    ) -> MutexGuard<'a, Option<Arc<RelVersion>>> {
        match slot.published.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                slot.published.clear_poison();
                let g = poisoned.into_inner();
                let mut scans = self.lock_scans(slot);
                if scans.poison_bump(slot.epoch_hint.load(Ordering::Acquire)) {
                    self.scan_stats.invalidations.fetch_add(1, Ordering::Relaxed);
                }
                g
            }
        }
    }

    /// Pin the current published version of `rel`, materializing epoch 0
    /// from the load image on first use. The lock is held only for the
    /// pointer clone (or the one-time load), never for query execution.
    fn snapshot(&self, rel: RelId) -> Arc<RelVersion> {
        let slot = self.slot(rel);
        let mut g = self.lock_published(slot);
        if let Some(v) = g.as_ref() {
            return Arc::clone(v);
        }
        let r = self.db.rel(rel);
        let rlayout = self.layout.rel(rel);
        let states = Arc::new(engine::load_states(
            r,
            rlayout,
            self.cfg.xbar_cols,
            0..r.records,
        ));
        let stats = Arc::new(RelStats::build(&states, rlayout));
        let v = Arc::new(RelVersion {
            epoch: 0,
            states,
            stats,
        });
        *g = Some(Arc::clone(&v));
        v
    }

    /// Execute a prepared statement (see [`Prepared::execute`]).
    fn execute_prepared(
        &self,
        p: &Prepared<'_>,
        engine_kind: EngineKind,
    ) -> Result<QueryResult, PimdbError> {
        let compiled = &p.plan.compiled;

        // Pin one snapshot per touched relation for the whole query:
        // every program sees the same committed version, and a DML batch
        // committing mid-execution is invisible — the published pointer
        // moves, the pinned Arc does not. No lock is held across
        // execution from here on.
        let rels: BTreeSet<RelId> = compiled.iter().map(|c| c.rel).collect();
        let versions: BTreeMap<RelId, Arc<RelVersion>> =
            rels.into_iter().map(|r| (r, self.snapshot(r))).collect();

        let mut outs = Vec::with_capacity(compiled.len());
        for (i, (c, scan)) in compiled.iter().zip(&p.plan.scans).enumerate() {
            let version = &versions[&c.rel];
            let slot = self.slot(c.rel);
            let rlayout = self.layout.rel(c.rel);
            // Shared scan: replay a cached mask only when it was computed
            // against exactly this epoch (same mask function per the byte
            // key, same input data per the epoch tag), transplanting the
            // mask planes and running only the program's suffix. The
            // prefix writes nothing but compute columns and the suffix
            // never writes the mask column, so the replay is bit-identical
            // to the full run.
            let cached = scan
                .as_ref()
                .and_then(|info| self.lock_scans(slot).get(&info.key, version.epoch))
                .filter(|(m, _)| m.len() == version.states.len());
            // Zone-map pruning: a transplanted mask carries the skip
            // bitmap it was computed with (same epoch, same decision); a
            // fresh run derives it from the pinned snapshot's stats.
            let skip: CachedSkip = match &cached {
                Some((_, sk)) => Arc::clone(sk),
                None => Arc::new(prune::skip_bitmap(
                    &p.query.rels[i].filter,
                    rlayout,
                    &version.stats,
                )),
            };
            let seed = cached.map(|(m, _)| m);
            let steps = match (scan, &seed) {
                (Some(info), Some(_)) => &c.steps[info.prefix_len..],
                _ => &c.steps[..],
            };
            // the runtime all-zero short-circuit only applies to a full
            // run (a seeded suffix has no mask-writing steps to abandon)
            let sc = match (scan, &seed) {
                (Some(info), None) => prune::short_circuit(&c.steps, c.mask_col, info.prefix_len),
                _ => None,
            };
            let any_skip = skip.iter().any(|&b| b);
            let (out, masks) = self.pool.run_snapshot(
                &version.states,
                rlayout.compute_base,
                steps,
                c.mask_col,
                seed.as_ref(),
                any_skip.then_some(&skip),
                sc.as_ref(),
                engine_kind,
                &self.exec_plan,
            )?;
            if let Some(info) = scan {
                if seed.is_some() {
                    self.scan_stats.hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.lock_scans(slot).insert(
                        info.key.clone(),
                        version.epoch,
                        Arc::new(masks),
                        Arc::clone(&skip),
                    );
                    self.scan_stats.misses.fetch_add(1, Ordering::Relaxed);
                }
            }
            // Wear-tracked relations accumulate this program's write
            // profile into the reader ledger (folded into the committed
            // counters when the next batch begins). The wear model
            // charges the full *simulated* program even on a replay, a
            // skip, or a reordered execution — those are simulator
            // shortcuts and host scheduling choices, not changes to what
            // the simulated device does.
            {
                let mut book = self.lock_book(slot);
                if book.rows.is_some() {
                    let profile =
                        session::wear_profile(&p.plan.sim[i].steps, self.cfg.xbar_cols);
                    for (dst, add) in book.ledger.iter_mut().zip(&profile) {
                        *dst = dst.wrapping_add(*add);
                    }
                }
            }
            outs.push(out);
        }

        let output = session::assemble_output(&p.query, compiled, &outs);
        // metrics come from the plain-optimized twin: simulated cost is
        // independent of the host-side pruning/reordering schedule
        let mut metrics = session::simulate(&self.cfg, &p.query, &p.plan.sim, &self.layout);
        metrics.inter_cells = p
            .plan
            .sim
            .iter()
            .map(|c| c.peak_inter_cells)
            .max()
            .unwrap_or(0);
        metrics.opt = p.plan.opt;
        metrics.plan_cache = self.cache.counters();
        metrics.shards_skipped = outs.iter().map(|o| o.shards_skipped).sum();
        metrics.steps_short_circuited = outs.iter().map(|o| o.steps_short_circuited).sum();
        Ok(QueryResult::new(
            p.query.clone(),
            RunReport {
                query: p.query.name,
                metrics,
                output,
            },
        ))
    }

    /// Execute a batch of prepared statements as one fused unit on the
    /// native backend (see [`Pimdb::execute_batch_on`]).
    pub fn execute_batch(
        &self,
        stmts: &[&Prepared<'_>],
    ) -> Result<Vec<QueryResult>, PimdbError> {
        self.execute_batch_on(stmts, EngineKind::Native)
    }

    /// Execute a batch of prepared statements as one fused unit: pin one
    /// snapshot per touched relation, fuse the distinct shareable filter
    /// prefixes per relation into shared mask programs ([`fusion::fuse`]
    /// — cross-query common subexpressions computed once), run each
    /// fused program a single time over the shard executor, then execute
    /// every statement's suffix against its replayed mask.
    ///
    /// Results come back in batch order and are bit-identical — outputs,
    /// metrics, shared-scan counters, cache state and wear — to
    /// executing the statements serially with [`Prepared::execute`]: the
    /// fused scan is a simulator shortcut that shares work, not a change
    /// to what the simulated device computes or what each query is
    /// charged. The one exception is
    /// [`QueryMetrics::steps_short_circuited`], a host-runtime
    /// opportunity counter: a member whose prefix ran fused executes
    /// only its suffix (which has no mask-writing steps to abandon), so
    /// it reports 0 where its full serial run may report more.
    /// [`QueryMetrics::shards_skipped`] is identical on both paths — the
    /// skip bitmap travels with the cached mask.
    pub fn execute_batch_on(
        &self,
        stmts: &[&Prepared<'_>],
        engine_kind: EngineKind,
    ) -> Result<Vec<QueryResult>, PimdbError> {
        if stmts.is_empty() {
            return Ok(Vec::new());
        }

        // Phase 1 — pin one snapshot per touched relation for the whole
        // batch: every member (and every fused scan) sees the same
        // committed version of each relation, and a DML batch committing
        // mid-execution is invisible.
        let rels: BTreeSet<RelId> = stmts
            .iter()
            .flat_map(|p| p.plan.compiled.iter().map(|c| c.rel))
            .collect();
        let versions: BTreeMap<RelId, Arc<RelVersion>> =
            rels.into_iter().map(|r| (r, self.snapshot(r))).collect();

        // Phase 2 — per relation, fuse the distinct shareable prefixes
        // that are not already cached at the pinned epoch and run each
        // fused program once. Nothing is charged here: wear and scan
        // counters are charged per member below, exactly as serial
        // execution would.
        let mut by_rel: BTreeMap<RelId, Vec<(&sharedscan::ScanInfo, fusion::ScanProgram<'_>)>> =
            BTreeMap::new();
        for p in stmts {
            for (c, scan) in p.plan.compiled.iter().zip(&p.plan.scans) {
                let Some(info) = scan else { continue };
                if info.prefix_len == 0 {
                    continue;
                }
                let version = &versions[&c.rel];
                let cached = self
                    .lock_scans(self.slot(c.rel))
                    .get(&info.key, version.epoch)
                    .is_some_and(|(m, _)| m.len() == version.states.len());
                if cached {
                    continue;
                }
                let progs = by_rel.entry(c.rel).or_default();
                if progs.iter().any(|(i, _)| i.key == info.key) {
                    continue;
                }
                progs.push((
                    info,
                    fusion::ScanProgram {
                        steps: &c.steps[..info.prefix_len],
                        mask_col: c.mask_col,
                    },
                ));
            }
        }
        let mut produced: BTreeMap<(RelId, &[u8]), CachedMask> = BTreeMap::new();
        for (rel, progs) in &by_rel {
            let version = &versions[rel];
            let compute_base = self.layout.rel(*rel).compute_base;
            let members: Vec<fusion::ScanProgram<'_>> =
                progs.iter().map(|&(_, p)| p).collect();
            for chunk in fusion::fuse(&members, compute_base, self.cfg.xbar_cols) {
                let planes = self.pool.run_fused(
                    &version.states,
                    compute_base,
                    &chunk.steps,
                    &chunk.mask_cols,
                    engine_kind,
                    &self.exec_plan,
                )?;
                for (&m, mask) in chunk.members.iter().zip(planes) {
                    produced.insert((*rel, progs[m].0.key.as_slice()), Arc::new(mask));
                }
            }
        }

        // Phase 3 — shared-scan cache bookkeeping runs serially in batch
        // order: hit/miss counters, insert order and FIFO eviction state
        // end up bit-identical to executing the statements one at a
        // time. A member whose prefix was fused charges the same miss
        // (and populates the same cache entry) its full serial run
        // would have — the suffix never writes the mask column, so the
        // fused prefix's mask plane equals the full run's.
        let mut seeds: Vec<Vec<Option<(CachedMask, CachedSkip)>>> =
            Vec::with_capacity(stmts.len());
        for p in stmts {
            let mut per_stmt = Vec::with_capacity(p.plan.compiled.len());
            for (i, (c, scan)) in p.plan.compiled.iter().zip(&p.plan.scans).enumerate() {
                let seed = scan.as_ref().and_then(|info| {
                    let version = &versions[&c.rel];
                    let slot = self.slot(c.rel);
                    let cached = self
                        .lock_scans(slot)
                        .get(&info.key, version.epoch)
                        .filter(|(m, _)| m.len() == version.states.len());
                    match cached {
                        Some(pair) => {
                            self.scan_stats.hits.fetch_add(1, Ordering::Relaxed);
                            Some(pair)
                        }
                        None => match produced.get(&(c.rel, info.key.as_slice())) {
                            Some(m) => {
                                // a freshly fused mask enters the cache
                                // with the skip bitmap of the pinned
                                // version, exactly like a serial miss
                                let skip = Arc::new(prune::skip_bitmap(
                                    &p.query.rels[i].filter,
                                    self.layout.rel(c.rel),
                                    &version.stats,
                                ));
                                self.lock_scans(slot).insert(
                                    info.key.clone(),
                                    version.epoch,
                                    Arc::clone(m),
                                    Arc::clone(&skip),
                                );
                                self.scan_stats.misses.fetch_add(1, Ordering::Relaxed);
                                Some((Arc::clone(m), skip))
                            }
                            // the mask was cached when Phase 2 peeked
                            // but purged since (concurrent DML): fall
                            // back to the serial miss path — the member
                            // runs in full below and populates the
                            // cache itself.
                            None => None,
                        },
                    }
                });
                per_stmt.push(seed);
            }
            seeds.push(per_stmt);
        }

        // Phase 4 — every statement's remaining work (suffix runs,
        // output assembly, metric simulation, wear) executes
        // concurrently over the always-on pool.
        let mut results: Vec<Option<Result<QueryResult, PimdbError>>> =
            (0..stmts.len()).map(|_| None).collect();
        std::thread::scope(|s| {
            for ((p, sd), res) in stmts.iter().zip(&seeds).zip(&mut results) {
                let versions = &versions;
                s.spawn(move || {
                    *res = Some(self.finish_batch_member(p, sd, versions, engine_kind));
                });
            }
        });
        results
            .into_iter()
            .map(|r| r.expect("every batch member thread fills its slot"))
            .collect()
    }

    /// One batch member's tail: suffix (or full) runs per relation
    /// program against the batch-pinned snapshots, wear accounting and
    /// result assembly — the body of [`Pimdb::execute_prepared`] with
    /// snapshot pinning and cache accounting hoisted into the batch
    /// phases.
    fn finish_batch_member(
        &self,
        p: &Prepared<'_>,
        seeds: &[Option<(CachedMask, CachedSkip)>],
        versions: &BTreeMap<RelId, Arc<RelVersion>>,
        engine_kind: EngineKind,
    ) -> Result<QueryResult, PimdbError> {
        let compiled = &p.plan.compiled;
        let mut outs = Vec::with_capacity(compiled.len());
        for (i, ((c, scan), seed)) in compiled.iter().zip(&p.plan.scans).zip(seeds).enumerate() {
            let version = &versions[&c.rel];
            let slot = self.slot(c.rel);
            let rlayout = self.layout.rel(c.rel);
            // a transplanted (or fused) mask carries its skip bitmap; a
            // full run derives one from the pinned snapshot's stats
            let skip: CachedSkip = match seed {
                Some((_, sk)) => Arc::clone(sk),
                None => Arc::new(prune::skip_bitmap(
                    &p.query.rels[i].filter,
                    rlayout,
                    &version.stats,
                )),
            };
            let steps = match (scan, seed) {
                (Some(info), Some(_)) => &c.steps[info.prefix_len..],
                _ => &c.steps[..],
            };
            let sc = match (scan, seed) {
                (Some(info), None) => prune::short_circuit(&c.steps, c.mask_col, info.prefix_len),
                _ => None,
            };
            let any_skip = skip.iter().any(|&b| b);
            let (out, masks) = self.pool.run_snapshot(
                &version.states,
                rlayout.compute_base,
                steps,
                c.mask_col,
                seed.as_ref().map(|(m, _)| m),
                any_skip.then_some(&skip),
                sc.as_ref(),
                engine_kind,
                &self.exec_plan,
            )?;
            if let (Some(info), None) = (scan, seed) {
                // the Phase-2/3 fallback: this member ran in full, so it
                // populates the cache exactly like a serial miss
                self.lock_scans(slot).insert(
                    info.key.clone(),
                    version.epoch,
                    Arc::new(masks),
                    Arc::clone(&skip),
                );
                self.scan_stats.misses.fetch_add(1, Ordering::Relaxed);
            }
            {
                let mut book = self.lock_book(slot);
                if book.rows.is_some() {
                    let profile =
                        session::wear_profile(&p.plan.sim[i].steps, self.cfg.xbar_cols);
                    for (dst, add) in book.ledger.iter_mut().zip(&profile) {
                        *dst = dst.wrapping_add(*add);
                    }
                }
            }
            outs.push(out);
        }

        let output = session::assemble_output(&p.query, compiled, &outs);
        // metrics from the plain-optimized twin, as in execute_prepared
        let mut metrics = session::simulate(&self.cfg, &p.query, &p.plan.sim, &self.layout);
        metrics.inter_cells = p
            .plan
            .sim
            .iter()
            .map(|c| c.peak_inter_cells)
            .max()
            .unwrap_or(0);
        metrics.opt = p.plan.opt;
        metrics.plan_cache = self.cache.counters();
        metrics.shards_skipped = outs.iter().map(|o| o.shards_skipped).sum();
        metrics.steps_short_circuited = outs.iter().map(|o| o.steps_short_circuited).sum();
        Ok(QueryResult::new(
            p.query.clone(),
            RunReport {
                query: p.query.name,
                metrics,
                output,
            },
        ))
    }

    /// Prepare one DML statement: parse (if text) and compile once — or
    /// fetch the compiled form from the plan cache (canonical DML
    /// serialization keys, see [`cache::dml_key`]; prepared DML is
    /// cacheable exactly like prepared queries, and the schema
    /// fingerprint is shared) — and return the executable statement.
    pub fn prepare_dml<'q>(
        &self,
        source: impl Into<DmlSource<'q>>,
    ) -> Result<PreparedDml<'_>, PimdbError> {
        let dml = match source.into() {
            DmlSource::Pql(text) => {
                lang::parse_dml(text).map_err(|diag| PimdbError::Parse {
                    diag,
                    src: text.to_string(),
                })?
            }
            DmlSource::Ast(d) => d.clone(),
        };
        let rel = dml.rel();
        if !rel.in_pim() {
            // the PQL lowering rejects this with a spanned diagnostic;
            // AST-built statements get the typed error here instead of a
            // layout panic
            return Err(CompileError::NotPimResident { rel }.into());
        }
        let key = cache::dml_bytes(&dml, self.fingerprint);
        let plan = self.cache.get_or_compile_dml(key, || {
            Ok(CachedDmlPlan {
                compiled: compile_dml(&dml, self.layout.rel(rel), self.cfg.xbar_cols)?,
            })
        })?;
        Ok(PreparedDml {
            handle: self,
            dml,
            plan,
        })
    }

    /// Execute one DML statement against the resident PIM copy: INSERT
    /// writes the encoded record into the least-worn free row and sets
    /// its VALID bit; UPDATE filters (live rows only) and rewrites the
    /// SET attributes in place; DELETE filters and clears VALID (and the
    /// row data, keeping the all-zero-dead-row invariant the optimizer's
    /// zero-row reasoning relies on). Returns rows affected, the wear
    /// delta and the simulated application cost.
    ///
    /// The statement commits atomically through the relation's
    /// group-commit batch: queries concurrently in flight keep their
    /// pre-batch snapshots, and queries started after the commit see
    /// every effect.
    ///
    /// ```
    /// use pimdb::api::Pimdb;
    /// use pimdb::config::SystemConfig;
    /// use pimdb::db::dbgen::Database;
    ///
    /// let db = Pimdb::open(SystemConfig::default(), Database::generate(0.001, 42))?;
    /// let del = db.execute_dml("delete from supplier where s_suppkey <= 3")?;
    /// assert_eq!(del.rows_affected, 3);
    /// let ins = db.execute_dml(
    ///     "insert into supplier (s_suppkey, s_nationkey, s_acctbal) \
    ///      values (10001, 7, 1000.00)",
    /// )?;
    /// assert_eq!(ins.rows_affected, 1);
    /// // deleted rows are invisible to every filter and aggregate
    /// let n = db.prepare("from supplier | filter s_suppkey <= 3 \
    ///                     | aggregate count() as n")?.execute()?;
    /// assert_eq!(n.rows().row(0).unwrap().get("n").unwrap().as_i64(), Some(0));
    /// # Ok::<(), pimdb::error::PimdbError>(())
    /// ```
    pub fn execute_dml<'q>(
        &self,
        source: impl Into<DmlSource<'q>>,
    ) -> Result<DmlResult, PimdbError> {
        self.prepare_dml(source)?.execute()
    }

    /// Execute a prepared DML statement (see [`PreparedDml::execute`]):
    /// enqueue the request, then either an earlier writer's batch picks
    /// it up while we wait at the gate, or we win the gate and lead the
    /// batch ourselves.
    fn execute_dml_prepared(
        &self,
        p: &PreparedDml<'_>,
        engine_kind: EngineKind,
    ) -> Result<DmlResult, PimdbError> {
        let rel = p.dml.rel();
        let slot = self.slot(rel);
        let my = Arc::new(DmlSlot {
            done: Mutex::new(None),
        });
        // On a durable handle every request carries its canonical AST
        // bytes so whichever thread leads the batch can frame the WAL
        // record without re-borrowing the statement.
        let bytes = self
            .durability
            .as_ref()
            .map(|_| cache::dml_bytes(&p.dml, self.fingerprint));
        lock_plain(&slot.queue).push(DmlRequest {
            plan: Arc::clone(&p.plan),
            engine_kind,
            slot: Arc::clone(&my),
            bytes,
        });
        let _gate = lock_plain(&slot.gate);
        if let Some(done) = lock_plain(&my.done).take() {
            // a batch led by an earlier writer carried our request
            return done;
        }
        let batch: Vec<DmlRequest> = std::mem::take(&mut *lock_plain(&slot.queue));
        debug_assert!(!batch.is_empty(), "own request was queued above");
        self.apply_batch(rel, batch);
        lock_plain(&my.done)
            .take()
            .expect("the leader fills every drained slot")
    }

    /// Apply one drained batch of DML requests as a single commit: clone
    /// the pinned version, execute every statement against the private
    /// clone with **no facade lock held**, then either commit-and-publish
    /// (all statements succeeded) or abort (any failed — the published
    /// version and the committed row map stay untouched). Fills every
    /// request's result slot. The caller holds the relation's gate.
    fn apply_batch(&self, rel: RelId, batch: Vec<DmlRequest>) {
        let slot = self.slot(rel);

        // Unwind safety: on a leader panic, abort the in-flight batch
        // bookkeeping and fill every still-empty slot so follower
        // threads never hang (the book's own poison recovery is the
        // second line of defense when the panic holds that lock).
        struct BatchGuard<'a> {
            handle: &'a Pimdb,
            rel: RelId,
            batch: &'a [DmlRequest],
            done: bool,
        }
        impl Drop for BatchGuard<'_> {
            fn drop(&mut self) {
                if self.done {
                    return;
                }
                let slot = self.handle.slot(self.rel);
                let mut book = self.handle.lock_book(slot);
                if book.rows.as_ref().is_some_and(|r| r.in_batch()) {
                    book.rows.as_mut().expect("checked above").abort_batch();
                }
                drop(book);
                for req in self.batch {
                    let mut d = lock_plain(&req.slot.done);
                    if d.is_none() {
                        *d = Some(Err(ExecError::Backend {
                            engine: "native",
                            msg: "DML batch leader panicked".into(),
                        }
                        .into()));
                    }
                }
            }
        }
        let mut guard = BatchGuard {
            handle: self,
            rel,
            batch: &batch,
            done: false,
        };

        let version = self.snapshot(rel);
        let mut fold: Vec<(u32, u64)> = Vec::new();
        let mut pending = {
            let mut book = self.lock_book(slot);
            let RelBook { rows, ledger } = &mut *book;
            let rows = rows.get_or_insert_with(|| {
                // shadow the load image's liveness exactly — a mutated
                // store republishes with dead slots between live ones
                let r = self.db.rel(rel);
                let capacity = version.states.len() * XBAR_ROWS;
                let flags: Vec<bool> = (0..r.records).map(|i| r.live(i)).collect();
                EpochRowMap::new(FreeRowMap::from_flags(&flags, capacity, XBAR_ROWS))
            });
            debug_assert_eq!(
                rows.epoch(),
                version.epoch,
                "book and published version move in lockstep"
            );
            // reader wear observed since the last batch becomes committed
            // wear *before* the allocator looks at row heat, so placement
            // decisions match the legacy charge-immediately facade
            if ledger.iter().any(|&w| w != 0) {
                if self.durability.is_some() {
                    // the charged profile rides in this batch's WAL record
                    // so replay ranks allocator rows identically
                    fold = ledger
                        .iter()
                        .enumerate()
                        .filter(|(_, &w)| w != 0)
                        .map(|(i, &w)| (i as u32, w))
                        .collect();
                }
                rows.charge_profile(ledger);
                ledger.fill(0);
            }
            rows.begin_batch()
        };

        // The batch body: no facade lock held — concurrent readers keep
        // pinning and scanning the published (pre-batch) version.
        let mut states: Vec<XbarState> = (*version.states).clone();
        let mut results: Vec<Result<DmlResult, PimdbError>> = Vec::with_capacity(batch.len());
        let mut aborted = false;
        for req in &batch {
            let r = session::exec_dml_on_states(
                &self.cfg,
                &self.layout,
                rel,
                &mut states,
                &mut pending,
                &req.plan.compiled,
                req.engine_kind,
                &self.exec_plan,
            );
            aborted = r.is_err();
            results.push(r);
            if aborted {
                break;
            }
        }

        // Write-ahead: the batch's record must be on the log before its
        // epoch publishes. An append failure aborts the whole batch with
        // the I/O error — clients never observe a commit that recovery
        // could not reproduce. (Aborted batches log nothing.)
        let mut wal_err: Option<PimdbError> = None;
        if !aborted {
            if let Some(d) = &self.durability {
                let record = WalRecord {
                    rel_tag: WalRecord::tag_of(rel),
                    epoch: version.epoch + 1,
                    fold: std::mem::take(&mut fold),
                    stmts: batch
                        .iter()
                        .map(|req| {
                            req.bytes
                                .clone()
                                .expect("durable handles serialize every request")
                        })
                        .collect(),
                };
                if let Err(e) = d.append(&record) {
                    aborted = true;
                    wal_err = Some(e);
                }
            }
        }

        {
            let mut book = self.lock_book(slot);
            let rows = book.rows.as_mut().expect("created above");
            if aborted {
                // all-or-nothing: the private clone is dropped, the
                // published version and committed map are untouched
                rows.abort_batch();
            } else {
                rows.commit_batch(pending);
                let epoch = rows.epoch();
                drop(book);
                // incremental zone-map maintenance: only crossbars whose
                // planes this batch actually touched are recomputed
                let states = Arc::new(states);
                let stats = Arc::new(RelStats::update(
                    &version.stats,
                    &version.states,
                    &states,
                    self.layout.rel(rel),
                ));
                *self.lock_published(slot) = Some(Arc::new(RelVersion {
                    epoch,
                    states,
                    stats,
                }));
                slot.epoch_hint.store(epoch, Ordering::Release);
                // masks computed against older versions can never be
                // replayed again: readers that pinned before this commit
                // carry their own older epoch, readers after pin `epoch`
                if self.lock_scans(slot).purge_below(epoch) {
                    self.scan_stats.invalidations.fetch_add(1, Ordering::Relaxed);
                }
            }
        }

        let mut results = results.into_iter();
        for req in &batch {
            let res = match results.next() {
                Some(r) if !aborted => r,
                _ if wal_err.is_some() => Err(wal_err.clone().expect("checked above")),
                Some(Err(e)) => Err(e),
                _ => Err(ExecError::Backend {
                    engine: "native",
                    msg: "DML batch aborted by a failing statement".into(),
                }
                .into()),
            };
            *lock_plain(&req.slot.done) = Some(res);
        }
        guard.done = true;
    }

    /// Deliberately poison the scan-mask cache of `rel` (a helper thread
    /// panics while holding the lock) — test-only, for exercising the
    /// epoch-floor poison recovery.
    #[cfg(test)]
    fn poison_scan_cache(&self, rel: RelId) {
        let slot = self.slot(rel);
        std::thread::scope(|s| {
            let t = s.spawn(|| {
                let _g = slot.scans.lock().unwrap();
                panic!("poison the scan cache");
            });
            assert!(t.join().is_err(), "the helper must panic");
        });
        assert!(slot.scans.is_poisoned());
    }
}

/// Rebind aggregate output labels of a cached plan to the labels of the
/// *prepared* query. The cache key is alias-insensitive, so a hit may
/// carry the labels of whichever alias-variant compiled first; the
/// compiler emits exactly one [`crate::query::compiler::OutputSpec`] per
/// `(group, aggregate)` in aggregate order, which makes the rebinding a
/// positional rewrite. Returns the input `Arc` untouched when the labels
/// already match (the common case).
fn rebind_labels(plan: Arc<CachedPlan>, query: &Query) -> Arc<CachedPlan> {
    let matches = plan.compiled.iter().zip(&query.rels).all(|(c, rq)| {
        let n = rq.aggregates.len();
        n == 0
            || c.outputs
                .iter()
                .enumerate()
                .all(|(j, s)| s.label == rq.aggregates[j % n].label)
    });
    if matches {
        return plan;
    }
    let rebind = |programs: &[CompiledRelQuery]| {
        programs
            .iter()
            .zip(&query.rels)
            .map(|(c, rq)| {
                let mut c = c.clone();
                let n = rq.aggregates.len();
                if n > 0 {
                    for (j, spec) in c.outputs.iter_mut().enumerate() {
                        debug_assert_eq!(spec.kind, rq.aggregates[j % n].kind);
                        spec.label = rq.aggregates[j % n].label;
                    }
                }
                c
            })
            .collect()
    };
    Arc::new(CachedPlan {
        compiled: rebind(&plan.compiled),
        sim: rebind(&plan.sim),
        scans: plan.scans.clone(),
        opt: plan.opt,
    })
}

/// A prepared statement: the parsed query plus its compiled, optimized
/// plan (shared with the handle's plan cache). Executing takes `&self` —
/// the same statement can run concurrently from several threads, every
/// execution pins its own relation snapshots, and no execution ever
/// waits on concurrent DML.
pub struct Prepared<'db> {
    handle: &'db Pimdb,
    query: Query,
    plan: Arc<CachedPlan>,
}

impl Prepared<'_> {
    /// The query this statement executes.
    pub fn query(&self) -> &Query {
        &self.query
    }

    /// Execute on the native functional backend.
    pub fn execute(&self) -> Result<QueryResult, PimdbError> {
        self.execute_on(EngineKind::Native)
    }

    /// Execute on an explicit functional backend.
    pub fn execute_on(&self, engine_kind: EngineKind) -> Result<QueryResult, PimdbError> {
        self.handle.execute_prepared(self, engine_kind)
    }
}

/// A prepared DML statement: the parsed statement plus its compiled form
/// (shared with the handle's plan cache). Executing takes `&self` and
/// joins the target relation's group-commit batch — concurrent writers
/// on the same relation batch together behind one leader, writers on
/// other relations proceed in parallel, and concurrent queries observe
/// either the pre- or post-batch state, never a torn one.
pub struct PreparedDml<'db> {
    handle: &'db Pimdb,
    dml: Dml,
    plan: Arc<CachedDmlPlan>,
}

impl PreparedDml<'_> {
    /// The statement this prepared form executes.
    pub fn dml(&self) -> &Dml {
        &self.dml
    }

    /// Execute on the native functional backend.
    pub fn execute(&self) -> Result<DmlResult, PimdbError> {
        self.execute_on(EngineKind::Native)
    }

    /// Execute on an explicit functional backend.
    pub fn execute_on(&self, engine_kind: EngineKind) -> Result<DmlResult, PimdbError> {
        self.handle.execute_dml_prepared(self, engine_kind)
    }
}

/// One execution's result: decoded, typed rows plus the full simulated
/// metric set.
pub struct QueryResult {
    report: RunReport,
    rows: Vec<Row>,
}

impl QueryResult {
    fn new(query: Query, report: RunReport) -> QueryResult {
        let rows = rows::decode_rows(&query, &report.output);
        QueryResult { report, rows }
    }

    /// Name of the executed query.
    pub fn query_name(&self) -> &'static str {
        self.report.query
    }

    /// Cursor over the decoded result rows: one row per group for full
    /// queries, one `(relation, selected)` row per relation for
    /// filter-only queries.
    pub fn rows(&self) -> Rows<'_> {
        Rows::new(&self.rows)
    }

    /// The simulated timing/energy/power/endurance metrics, including the
    /// plan-cache counters at execution time.
    pub fn metrics(&self) -> &QueryMetrics {
        &self.report.metrics
    }

    /// The raw engine report (encoded outputs, paper-report shape). The
    /// escape hatch for the report generators and the differential suite;
    /// prefer [`QueryResult::rows`] for consuming results.
    pub fn raw_report(&self) -> &RunReport {
        &self.report
    }

    /// Consume the result into the raw engine report.
    pub fn into_report(self) -> RunReport {
        self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::pimdb::PimSession;

    fn db() -> Database {
        Database::generate(0.001, 11)
    }

    #[test]
    fn open_prepare_execute_matches_the_legacy_session() {
        let cfg = SystemConfig::default();
        let data = db();
        let mut legacy = PimSession::new(&cfg, &data).unwrap();
        let handle = Pimdb::open(cfg.clone(), db()).unwrap();
        for name in ["Q6", "Q1", "Q12"] {
            let q = tpch::query(name).unwrap();
            let want = legacy.run_query(&q, EngineKind::Native).unwrap();
            let got = handle.prepare(QuerySource::Tpch(name)).unwrap().execute().unwrap();
            assert_eq!(want.output, got.raw_report().output, "{name}");
            assert_eq!(
                want.metrics.cycles,
                got.metrics().cycles,
                "{name}"
            );
            assert_eq!(
                want.metrics.exec_time_s.to_bits(),
                got.metrics().exec_time_s.to_bits(),
                "{name}"
            );
        }
    }

    #[test]
    fn preparing_twice_compiles_once() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let src = "from supplier | filter s_suppkey < 50 | aggregate count() as n";
        let p1 = handle.prepare(src).unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 0, misses: 1 }
        );
        // reformatted + re-aliased: same template, cache hit
        let p2 = handle
            .prepare("from supplier\n  | filter s_suppkey < 50\n  | aggregate count() as how_many")
            .unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 1, misses: 1 }
        );
        let r1 = p1.execute().unwrap();
        let r2 = p2.execute().unwrap();
        // the rebound alias shows up in the typed rows of the hit
        assert!(r1.rows().row(0).unwrap().get("n").is_some());
        assert!(r2.rows().row(0).unwrap().get("how_many").is_some());
        assert_eq!(
            r1.rows().row(0).unwrap().get("n"),
            r2.rows().row(0).unwrap().get("how_many")
        );
        // counters surface in the metrics
        assert_eq!(
            r2.metrics().plan_cache,
            PlanCacheCounters { hits: 1, misses: 1 }
        );
        // a literal change misses
        handle
            .prepare("from supplier | filter s_suppkey < 51 | aggregate count() as n")
            .unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 1, misses: 2 }
        );
    }

    #[test]
    fn prepare_rejects_multi_block_programs_and_unknown_names() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let program = "query a from part | filter true ; query b from supplier | filter true";
        match handle.prepare(program) {
            Err(PimdbError::ExpectedSingleQuery { found }) => assert_eq!(found, 2),
            other => panic!("expected ExpectedSingleQuery, got {:?}", other.map(|_| ())),
        }
        assert_eq!(handle.prepare_all(program).unwrap().len(), 2);
        assert!(matches!(
            handle.prepare(QuerySource::Tpch("Q99")),
            Err(PimdbError::UnknownQuery(_))
        ));
        assert!(matches!(
            handle.prepare("from lineitem | filter nope < 3"),
            Err(PimdbError::Parse { .. })
        ));
    }

    #[test]
    fn dml_prepares_cache_and_execute_mutates_the_pim_copy() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let src = "update supplier set s_nationkey = 3 where s_suppkey <= 10";
        let p1 = handle.prepare_dml(src).unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 0, misses: 1 }
        );
        let p2 = handle.prepare_dml(src).unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 1, misses: 1 }
        );
        assert_eq!(p2.dml().kind_name(), "update");
        let r = p1.execute().unwrap();
        assert_eq!(r.rows_affected, 10);
        assert!(r.wear_delta > 0.0);
        assert!(r.metrics.exec_time_s > 0.0);
        // every committed batch bumps the relation epoch
        assert_eq!(handle.relation_epoch(crate::db::schema::RelId::Supplier), 1);
        // the rewrite is visible to queries through the same handle
        let n = handle
            .prepare(
                "from supplier | filter s_nationkey == 3 and s_suppkey <= 10 \
                 | aggregate count() as n",
            )
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(n.raw_report().output.groups[0].count, 10);
        // a literal change is a different DML plan (cache miss)
        handle
            .prepare_dml("update supplier set s_nationkey = 4 where s_suppkey <= 10")
            .unwrap();
        let c = handle.plan_cache_counters();
        assert_eq!(c.misses, 3); // 2 dml templates + 1 query
        // query text given to prepare_dml is a typed parse error
        assert!(matches!(
            handle.prepare_dml("from supplier | filter true"),
            Err(PimdbError::Parse { .. })
        ));
        // AST-built DML on a DRAM-resident relation is a typed error,
        // not a layout panic
        let dram = Dml::Delete {
            rel: crate::db::schema::RelId::Nation,
            filter: crate::query::ast::Pred::True,
        };
        assert!(matches!(
            handle.execute_dml(&dram),
            Err(PimdbError::Compile(CompileError::NotPimResident { .. }))
        ));
        // clear_plan_cache drops DML plans too: re-preparing recompiles
        handle.clear_plan_cache();
        handle.prepare_dml(src).unwrap();
        assert_eq!(handle.plan_cache_counters().misses, 4);
    }

    #[test]
    fn open_rejects_admission_caps_below_the_worker_count() {
        let cfg = SystemConfig {
            parallelism: 4,
            admission: 2,
            ..SystemConfig::default()
        };
        match Pimdb::open(cfg, db()) {
            Err(PimdbError::Config(msg)) => {
                assert!(msg.contains("admission cap 2"), "{msg}");
                assert!(msg.contains("parallelism 4"), "{msg}");
            }
            other => panic!("expected Config error, got {:?}", other.map(|_| ())),
        }
        // 0 stays the documented auto cap; explicit caps at or above the
        // worker count are accepted
        for admission in [0, 4, 64] {
            let cfg = SystemConfig {
                parallelism: 4,
                admission,
                ..SystemConfig::default()
            };
            assert!(Pimdb::open(cfg, db()).is_ok(), "admission {admission}");
        }
    }

    /// ScanMaskCache FIFO eviction under epoch churn: filling past the
    /// 8-entry cap evicts the oldest key, evicted keys re-run as misses,
    /// resident keys replay as hits, and every group-commit purges the
    /// cache so a stale-epoch mask is never replayed.
    #[test]
    fn scan_cache_fifo_eviction_under_epoch_churn() {
        use crate::db::schema::RelId;
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let sources: Vec<String> = (0..=MAX_CACHED_SCANS)
            .map(|i| {
                format!(
                    "from supplier | filter s_suppkey < {} | aggregate count() as n",
                    11 + i
                )
            })
            .collect();
        let stmts: Vec<Prepared<'_>> = sources
            .iter()
            .map(|s| handle.prepare(s.as_str()).unwrap())
            .collect();
        // 9 distinct prefixes fill the 8-entry cache and evict the oldest
        for (i, p) in stmts.iter().enumerate() {
            assert_eq!(
                p.execute().unwrap().raw_report().output.groups[0].count,
                10 + i as u64
            );
        }
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 0,
                misses: 9,
                invalidations: 0
            }
        );
        // the first key was evicted (FIFO): re-running it is a fresh
        // miss...
        stmts[0].execute().unwrap();
        // ...the newest key is still resident: a hit...
        stmts[8].execute().unwrap();
        // ...and re-inserting key 0 evicted the then-oldest key 1
        stmts[1].execute().unwrap();
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 1,
                misses: 11,
                invalidations: 0
            }
        );

        // epoch churn: each group-commit purges the resident masks, and
        // the post-commit re-run misses and sees every deletion so far —
        // a stale-epoch mask never replays
        for round in 0..3u64 {
            handle
                .execute_dml(
                    format!("delete from supplier where s_suppkey == {}", round + 1).as_str(),
                )
                .unwrap();
            assert_eq!(handle.relation_epoch(RelId::Supplier), round + 1);
            assert_eq!(handle.shared_scan_counters().invalidations, round + 1);
            let n = stmts[8].execute().unwrap().raw_report().output.groups[0].count;
            assert_eq!(n, 18 - (round + 1));
            // the refilled mask replays at the new epoch
            let again = stmts[8].execute().unwrap().raw_report().output.groups[0].count;
            assert_eq!(again, n);
        }
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 4,
                misses: 14,
                invalidations: 3
            }
        );
    }

    /// `execute_batch` is bit-identical to serial execution — outputs,
    /// metrics, shared-scan counters and cache state all match — while
    /// the distinct filter prefixes run once through one fused program.
    #[test]
    fn execute_batch_matches_serial_execution_and_counters() {
        let sources = [
            "from supplier | filter s_suppkey < 50 | aggregate count() as n",
            "from supplier | filter s_suppkey < 50 | aggregate sum(s_acctbal) as s",
            "from supplier | filter s_suppkey < 25 | aggregate count() as n",
            "from supplier | filter s_acctbal > 100.00 | aggregate count() as n",
        ];
        let serial = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let batched = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let sp: Vec<_> = sources.iter().map(|s| serial.prepare(*s).unwrap()).collect();
        let bp: Vec<_> = sources.iter().map(|s| batched.prepare(*s).unwrap()).collect();
        let want: Vec<_> = sp.iter().map(|p| p.execute().unwrap()).collect();
        let refs: Vec<&Prepared<'_>> = bp.iter().collect();
        let got = batched.execute_batch(&refs).unwrap();
        assert_eq!(got.len(), want.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.raw_report().output, g.raw_report().output);
            assert_eq!(w.metrics().cycles, g.metrics().cycles);
            assert_eq!(
                w.metrics().exec_time_s.to_bits(),
                g.metrics().exec_time_s.to_bits()
            );
            assert_eq!(w.metrics().inter_cells, g.metrics().inter_cells);
        }
        // counter-for-counter the batch tells the serial story: three
        // distinct prefixes miss (one fused run produced all three
        // masks), the repeated prefix hits
        assert_eq!(serial.shared_scan_counters(), batched.shared_scan_counters());
        assert_eq!(
            batched.shared_scan_counters(),
            SharedScanCounters {
                hits: 1,
                misses: 3,
                invalidations: 0
            }
        );
        // re-batching replays every mask from the cache
        let again = batched.execute_batch(&refs).unwrap();
        assert_eq!(again[0].raw_report().output, want[0].raw_report().output);
        assert_eq!(batched.shared_scan_counters().hits, 5);
        // the empty batch is a no-op
        assert!(batched.execute_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn queries_on_mutated_relations_accumulate_wear() {
        use crate::db::schema::RelId;
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        // pristine relation: no wear tracking yet
        assert!(handle.wear_counters(RelId::Supplier).is_empty());
        handle
            .execute_dml("delete from supplier where s_suppkey == 1")
            .unwrap();
        let w1: u64 = handle.wear_counters(RelId::Supplier).iter().sum();
        assert!(w1 > 0, "DML charges wear");
        handle
            .prepare("from supplier | filter s_acctbal > 0.00 | aggregate count() as n")
            .unwrap()
            .execute()
            .unwrap();
        let w2: u64 = handle.wear_counters(RelId::Supplier).iter().sum();
        assert!(w2 > w1, "queries on mutated relations charge wear too");
        // the reader's ledger wear becomes committed wear at the next
        // batch without ever decreasing the observed totals
        handle
            .execute_dml("delete from supplier where s_suppkey == 2")
            .unwrap();
        let w3: u64 = handle.wear_counters(RelId::Supplier).iter().sum();
        assert!(w3 > w2, "wear stays monotone across the ledger fold");
        // other relations stay untracked until mutated
        assert!(handle.wear_counters(RelId::Part).is_empty());
    }

    #[test]
    fn dml_matches_the_legacy_session_path() {
        use crate::db::schema::RelId;
        use crate::query::lang::parse_dml;
        let cfg = SystemConfig::default();
        let data = db();
        let mut legacy = PimSession::new(&cfg, &data).unwrap();
        let handle = Pimdb::open(cfg.clone(), db()).unwrap();
        let statements = [
            "delete from supplier where s_acctbal < 100.00",
            "update supplier set s_phone_cc = 11 where s_nationkey == 1",
            "insert into supplier (s_suppkey, s_acctbal) values (9000, 50.00)",
        ];
        for src in statements {
            let dml = parse_dml(src).unwrap();
            let a = legacy.run_dml(&dml, EngineKind::Native).unwrap();
            let b = handle.execute_dml(&dml).unwrap();
            assert_eq!(a.rows_affected, b.rows_affected, "{src}");
            assert_eq!(a.wear_delta.to_bits(), b.wear_delta.to_bits(), "{src}");
            assert_eq!(
                a.metrics.exec_time_s.to_bits(),
                b.metrics.exec_time_s.to_bits(),
                "{src}"
            );
        }
        assert_eq!(
            legacy.live_records(RelId::Supplier),
            handle.live_records(RelId::Supplier)
        );
        // queries agree on the mutated state
        let q = tpch::query("Q11").unwrap();
        let a = legacy.run_query(&q, EngineKind::Native).unwrap();
        let b = handle.prepare(QuerySource::Ast(&q)).unwrap().execute().unwrap();
        assert_eq!(a.output, b.raw_report().output);
    }

    #[test]
    fn concurrent_execution_from_shared_reference() {
        let cfg = SystemConfig {
            parallelism: 2,
            ..SystemConfig::default()
        };
        let data = db();
        let mut legacy = PimSession::new(&cfg, &data).unwrap();
        let want_q6 = legacy
            .run_query(&tpch::query("Q6").unwrap(), EngineKind::Native)
            .unwrap();
        let want_q11 = legacy
            .run_query(&tpch::query("Q11").unwrap(), EngineKind::Native)
            .unwrap();

        let handle = Arc::new(Pimdb::open(cfg.clone(), db()).unwrap());
        let q6 = handle.prepare(QuerySource::Tpch("Q6")).unwrap();
        let q11 = handle.prepare(QuerySource::Tpch("Q11")).unwrap();
        std::thread::scope(|s| {
            let t6 = s.spawn(|| q6.execute().unwrap());
            let t11 = s.spawn(|| q11.execute().unwrap());
            let r6 = t6.join().unwrap();
            let r11 = t11.join().unwrap();
            assert_eq!(r6.raw_report().output, want_q6.output);
            assert_eq!(r11.raw_report().output, want_q11.output);
            assert_eq!(
                r6.metrics().exec_time_s.to_bits(),
                want_q6.metrics.exec_time_s.to_bits()
            );
        });
        // re-executing after the concurrent burst still matches
        let again = q6.execute().unwrap();
        assert_eq!(again.raw_report().output, want_q6.output);
    }

    #[test]
    fn shared_scans_replay_cached_filter_prefixes() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let count_src = "from supplier | filter s_suppkey < 50 | aggregate count() as n";
        let sum_src = "from supplier | filter s_suppkey < 50 | aggregate sum(s_acctbal) as s";
        let p_count = handle.prepare(count_src).unwrap();
        let p_sum = handle.prepare(sum_src).unwrap();
        // distinct plans over one relation share a canonical prefix key:
        // the suffix differs (count vs sum), the mask function does not
        let s1 = p_count.plan.scans[0].as_ref().expect("count plan is shareable");
        let s2 = p_sum.plan.scans[0].as_ref().expect("sum plan is shareable");
        assert!(s1.prefix_len > 0);
        assert_eq!(s1.key, s2.key, "same filter must normalize to one key");

        // oracle outputs from fresh handles (nothing cached, full runs)
        let fresh = |src: &str| {
            Pimdb::open(SystemConfig::default(), db())
                .unwrap()
                .prepare(src)
                .unwrap()
                .execute()
                .unwrap()
                .raw_report()
                .output
                .clone()
        };
        let want_count = fresh(count_src);
        let want_sum = fresh(sum_src);

        let r1 = p_count.execute().unwrap();
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 0,
                misses: 1,
                invalidations: 0
            }
        );
        // second statement replays the cached mask, runs only its suffix
        let r2 = p_sum.execute().unwrap();
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 1,
                misses: 1,
                invalidations: 0
            }
        );
        assert_eq!(r1.raw_report().output, want_count);
        assert_eq!(r2.raw_report().output, want_sum);

        // re-executing the first statement is a hit too, still exact
        let r3 = p_count.execute().unwrap();
        assert_eq!(r3.raw_report().output, want_count);
        assert_eq!(handle.shared_scan_counters().hits, 2);

        // a different literal is a different mask function: full run
        handle
            .prepare("from supplier | filter s_suppkey < 51 | aggregate count() as n")
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 2,
                misses: 2,
                invalidations: 0
            }
        );
    }

    #[test]
    fn dml_invalidates_cached_scan_masks() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let p = handle
            .prepare("from supplier | filter s_suppkey <= 10 | aggregate count() as n")
            .unwrap();
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 10);
        assert_eq!(handle.shared_scan_counters().misses, 1);
        // DML drops the relation's cached masks
        handle
            .execute_dml("delete from supplier where s_suppkey == 5")
            .unwrap();
        assert_eq!(handle.shared_scan_counters().invalidations, 1);
        // the re-run cannot replay the stale mask: it sees the deletion
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 9);
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 0,
                misses: 2,
                invalidations: 1
            }
        );
    }

    /// Regression (snapshot MVCC): a cached mask is pinned to the epoch
    /// it was computed against. After a DML commit the old mask must
    /// neither be replayed (deleted rows would leak into results) nor
    /// count as a hit; a mask recomputed at the new epoch replays again.
    #[test]
    fn shared_scan_masks_are_epoch_tagged_under_dml() {
        use crate::db::schema::RelId;
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let p = handle
            .prepare("from supplier | filter s_suppkey <= 10 | aggregate count() as n")
            .unwrap();
        assert_eq!(handle.relation_epoch(RelId::Supplier), 0);
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 10);
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 0,
                misses: 1,
                invalidations: 0
            }
        );
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 10);
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 1,
                misses: 1,
                invalidations: 0
            }
        );
        handle
            .execute_dml("delete from supplier where s_suppkey == 7")
            .unwrap();
        assert_eq!(handle.relation_epoch(RelId::Supplier), 1);
        // epoch moved: the cached epoch-0 mask is purged, the re-run is
        // a full miss and sees the deletion
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 9);
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 1,
                misses: 2,
                invalidations: 1
            }
        );
        // the epoch-1 mask replays for epoch-1 readers
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 9);
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 2,
                misses: 2,
                invalidations: 1
            }
        );
    }

    /// Poison recovery bumps the epoch floor: after a panic under the
    /// scan-cache lock, nothing resident (or in flight) is ever replayed
    /// and the cache stays cold at the poisoned epoch; it resumes at the
    /// next committed epoch.
    #[test]
    fn scan_cache_poison_recovery_disables_replay_until_the_next_epoch() {
        use crate::db::schema::RelId;
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let p = handle
            .prepare("from supplier | filter s_suppkey <= 10 | aggregate count() as n")
            .unwrap();
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 10);
        assert_eq!(handle.shared_scan_counters().misses, 1);

        handle.poison_scan_cache(RelId::Supplier);

        // recovery drops the resident mask (one invalidation) and the
        // floor rejects re-inserts at epoch 0: both runs are full misses
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 10);
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 10);
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 0,
                misses: 3,
                invalidations: 1
            }
        );

        // the next DML commit moves the relation to epoch 1 >= floor:
        // caching resumes, exact as ever
        handle
            .execute_dml("delete from supplier where s_suppkey == 3")
            .unwrap();
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 9);
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 9);
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 1,
                misses: 4,
                invalidations: 1
            }
        );
    }

    /// Concurrent single-row deletes on one relation group-commit: every
    /// statement reports exactly its own row, the final state equals the
    /// serial application, and liveness/epoch bookkeeping is race-free.
    #[test]
    fn concurrent_dml_group_commits_and_stays_serializable() {
        use crate::db::schema::RelId;
        let handle = Arc::new(Pimdb::open(SystemConfig::default(), db()).unwrap());
        let initial = handle.live_records(RelId::Supplier);
        let keys = [1u64, 2, 3, 4, 5, 6, 7, 8];
        std::thread::scope(|s| {
            for k in keys {
                let handle = Arc::clone(&handle);
                s.spawn(move || {
                    let r = handle
                        .execute_dml(
                            format!("delete from supplier where s_suppkey == {k}").as_str(),
                        )
                        .unwrap();
                    assert_eq!(r.rows_affected, 1, "key {k}");
                });
            }
        });
        assert_eq!(handle.live_records(RelId::Supplier), initial - keys.len());
        // at least one batch committed, at most one per statement
        let epoch = handle.relation_epoch(RelId::Supplier);
        assert!(epoch >= 1 && epoch <= keys.len() as u64);
        // a serial twin agrees on the final contents
        let serial = Pimdb::open(SystemConfig::default(), db()).unwrap();
        for k in keys {
            serial
                .execute_dml(format!("delete from supplier where s_suppkey == {k}").as_str())
                .unwrap();
        }
        let probe = "from supplier | filter s_acctbal >= 0.00 | aggregate sum(s_acctbal) as s";
        let a = handle.prepare(probe).unwrap().execute().unwrap();
        let b = serial.prepare(probe).unwrap().execute().unwrap();
        assert_eq!(a.raw_report().output, b.raw_report().output);
    }

    /// Zone-map pruning skips crossbars a selective key-range filter
    /// provably misses (lineitem loads in orderkey order, so only the
    /// leading crossbars contain small keys), the result stays exact
    /// against the host baseline, a shared-scan replay carries the same
    /// skip bitmap, and a DML batch that empties the selected range
    /// widens the skip set through incremental stats maintenance.
    #[test]
    fn zone_map_pruning_skips_shards_and_stays_exact() {
        use crate::db::schema::RelId;
        use crate::exec::baseline;
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let mut mirror = handle.database().clone();
        let p = handle
            .prepare("from lineitem | filter l_orderkey <= 64 | aggregate count() as n")
            .unwrap();

        let first = p.execute().unwrap();
        let oracle = baseline::run_query(handle.cfg(), &mirror, &p.query);
        assert_eq!(oracle.output, first.raw_report().output);
        assert!(
            first.metrics().shards_skipped > 0,
            "a selective key-range filter must skip trailing crossbars"
        );

        // replay path: the transplanted mask carries its skip bitmap, so
        // the seeded suffix run charges the identical skip count
        let replay = p.execute().unwrap();
        assert_eq!(handle.shared_scan_counters().hits, 1);
        assert_eq!(oracle.output, replay.raw_report().output);
        assert_eq!(
            replay.metrics().shards_skipped,
            first.metrics().shards_skipped
        );

        // deleting the whole selected range recomputes the mutated
        // crossbars' zones; every crossbar is now provably disjoint
        let d = lang::parse_dml("delete from lineitem where l_orderkey <= 64").unwrap();
        handle.prepare_dml(&d).unwrap().execute().unwrap();
        baseline::apply_dml(handle.cfg(), &mut mirror, &d);
        assert_eq!(handle.relation_epoch(RelId::Lineitem), 1);
        let after = p.execute().unwrap();
        let oracle = baseline::run_query(handle.cfg(), &mirror, &p.query);
        assert_eq!(oracle.output, after.raw_report().output);
        assert!(
            after.metrics().shards_skipped > first.metrics().shards_skipped,
            "emptying the range must widen the skip set ({} vs {})",
            after.metrics().shards_skipped,
            first.metrics().shards_skipped
        );
    }

    /// The runtime all-zero short-circuit abandons the rest of a filter
    /// prefix once contradictory conjuncts empty the mask — on a filter
    /// the zone maps cannot prune (every conjunct is individually
    /// satisfiable on every crossbar).
    #[test]
    fn runtime_short_circuit_abandons_contradictory_filters() {
        use crate::exec::baseline;
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let p = handle
            .prepare(
                "from lineitem | filter l_shipdate >= date(1994-06-01) \
                 and l_shipdate < date(1994-06-01) and l_quantity < 10 \
                 and l_quantity >= 10 | aggregate count() as n",
            )
            .unwrap();
        let r = p.execute().unwrap();
        let oracle = baseline::run_query(handle.cfg(), handle.database(), &p.query);
        assert_eq!(oracle.output, r.raw_report().output);
        assert_eq!(r.metrics().shards_skipped, 0, "zones cannot prune this");
        assert!(
            r.metrics().steps_short_circuited > 0,
            "the emptied mask must abandon the remaining filter steps"
        );
    }

    /// `--explain` surface: the pruning rendition names the relation,
    /// shows the per-shard skip bitmap and the zone ranges consulted.
    #[test]
    fn explain_pruning_renders_skip_bitmap_and_zones() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let text = handle
            .explain_pruning("from lineitem | filter l_orderkey <= 64 | aggregate count() as n")
            .unwrap();
        assert!(text.contains("lineitem: pruning (epoch 0"), "{text}");
        assert!(text.contains("skip bitmap"), "{text}");
        assert!(text.contains("crossbars skipped"), "{text}");
    }
}
