//! Activity-based host power model (McPAT substitute, paper §6.3).
//!
//! Host energy = busy-core energy + uncore/idle energy over the span.
//! With PIMDB the host mostly issues memory operations (light arithmetic),
//! so its energy share is small (paper Fig. 12) — the model only needs to
//! preserve that ordering.

use crate::config::SystemConfig;

/// Host energy for a run (pJ).
pub fn host_energy_pj(cfg: &SystemConfig, span_s: f64, core_busy_s: f64, cores: usize) -> f64 {
    let busy = cfg.core_active_w * core_busy_s * cores.min(cfg.exec_threads.max(cores)) as f64;
    let idle = cfg.host_idle_w * span_s;
    (busy + idle) * 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_floor_always_present() {
        let cfg = SystemConfig::default();
        let e = host_energy_pj(&cfg, 1.0, 0.0, 0);
        assert!((e - cfg.host_idle_w * 1e12).abs() < 1e-3);
    }

    #[test]
    fn busy_cores_add_energy() {
        let cfg = SystemConfig::default();
        let idle = host_energy_pj(&cfg, 1.0, 0.0, 0);
        let busy = host_energy_pj(&cfg, 1.0, 1.0, 4);
        assert!(busy > idle);
    }
}
