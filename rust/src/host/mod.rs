//! Host processor models: an analytic out-of-order core timing model and
//! an activity-based power model (McPAT substitute).

pub mod core;
pub mod power;
