//! Analytic out-of-order core timing model.
//!
//! The paper runs a gem5 OoO X86 core; the relevant first-order behaviour
//! for this memory-bound workload is (a) instruction throughput when data
//! is cached, (b) overlap of demand misses up to the core's memory-level
//! parallelism, (c) bandwidth saturation when streaming. The model takes
//! per-thread activity counts and returns the thread's execution time:
//!
//! `t = max(instr / (ipc * f),  misses * lat / MLP,  bytes / bw_share)`
//!
//! which is the standard roofline-style bound an OoO core approaches on
//! streaming scans (validated against the paper's baseline behaviour:
//! execution time tracks bytes/bandwidth for the big relations).

use crate::config::SystemConfig;

/// Per-thread activity summary produced by the executors.
#[derive(Clone, Copy, Debug, Default)]
pub struct Activity {
    /// Dynamic instructions retired (approximate).
    pub instructions: u64,
    /// L1 hits on the data path.
    pub l1_hits: u64,
    /// L2 hits on the data path.
    pub l2_hits: u64,
    /// LLC misses on the data path.
    pub llc_misses: u64,
    /// Bytes fetched from DRAM (LLC miss traffic incl. prefetch benefit).
    pub dram_bytes: u64,
}

/// Sustained scalar IPC on scan/filter loops.
const SCAN_IPC: f64 = 3.0;

/// Execution time of one thread's activity (seconds). `bw_share` is the
/// fraction of DRAM bandwidth available to this thread (1/threads when all
/// threads stream concurrently).
pub fn thread_time_s(cfg: &SystemConfig, a: &Activity, bw_share: f64) -> f64 {
    let compute = a.instructions as f64 / (SCAN_IPC * cfg.core_freq_hz);
    // L2 hits still cost pipeline slots; fold them into compute at the L2
    // hit latency divided by MLP overlap.
    let l2_time =
        a.l2_hits as f64 * cfg.l2_hit_cycles as f64 / cfg.core_freq_hz / cfg.host_mlp;
    let miss_time = a.llc_misses as f64 * (cfg.dram_latency_ns as f64 * 1e-9)
        / cfg.host_mlp;
    let stream_time = a.dram_bytes as f64 / (cfg.dram_bw_bps * bw_share.max(1e-9));
    (compute + l2_time).max(miss_time).max(stream_time)
}

/// Parallel region time: slowest thread wins (the executors partition
/// records evenly, so threads are near-balanced).
pub fn parallel_time_s(cfg: &SystemConfig, threads: &[Activity]) -> f64 {
    let share = 1.0 / threads.len().max(1) as f64;
    threads
        .iter()
        .map(|a| thread_time_s(cfg, a, share))
        .fold(0.0, f64::max)
}

/// Fixed software overheads (thread spawn/join, syscalls) — paper §6.1
/// counts these in "other operations".
pub fn spawn_join_overhead_s(cfg: &SystemConfig, threads: usize) -> f64 {
    // ~30k cycles per spawn/join pair
    30_000.0 * threads as f64 / cfg.core_freq_hz
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_bound_when_streaming() {
        let cfg = SystemConfig::default();
        let a = Activity {
            instructions: 1000,
            dram_bytes: 38_400_000_000, // 1 s at full bw
            llc_misses: 100,
            ..Default::default()
        };
        let t = thread_time_s(&cfg, &a, 1.0);
        assert!((t - 1.0).abs() < 0.01);
    }

    #[test]
    fn compute_bound_when_cached() {
        let cfg = SystemConfig::default();
        let a = Activity {
            instructions: 3_600_000_000, // ~0.33 s at IPC 3 / 3.6 GHz
            l1_hits: 1_000_000,
            ..Default::default()
        };
        let t = thread_time_s(&cfg, &a, 1.0);
        assert!((t - 1.0 / 3.0).abs() < 0.01);
    }

    #[test]
    fn mlp_overlaps_misses() {
        let cfg = SystemConfig::default();
        let a = Activity {
            llc_misses: 1_000_000,
            ..Default::default()
        };
        let serial = 1_000_000.0 * 80e-9;
        let t = thread_time_s(&cfg, &a, 1.0);
        assert!(t < serial / 5.0);
    }

    #[test]
    fn parallel_time_is_max_of_threads() {
        let cfg = SystemConfig::default();
        let small = Activity {
            instructions: 100,
            ..Default::default()
        };
        let big = Activity {
            instructions: 1_000_000_000,
            ..Default::default()
        };
        let t = parallel_time_s(&cfg, &[small, big]);
        assert!(t >= thread_time_s(&cfg, &big, 0.5) * 0.99);
    }
}
