//! Physical-address ↔ memory-cell mapping (paper Fig. 3).
//!
//! The programming model reveals which page-offset bits select the crossbar
//! index, the crossbar row, and the crossbar column, so user software can
//! target individual cells with loads/stores/PIM requests. The fields are
//! not consecutive: a 64-byte cache-line access retrieves 16 bits from each
//! of 32 crossbars (paper Table 3: crossbar read = 16 bits), which fixes
//! the low-order interleave.
//!
//! Default layout for 1 GB pages and 1024x512 crossbars (LSB -> MSB):
//!
//! ```text
//!   bit  0      : byte within the 16-bit crossbar read unit
//!   bits 1..=5  : crossbar index low  (32 crossbars per line access)
//!   bits 6..=10 : 16-bit unit within the crossbar row (512/16 = 32)
//!   bits 11..=20: crossbar row (1024)
//!   bits 21..=29: crossbar index high (total crossbar bits = 14 -> 16384)
//! ```

/// Location of a byte inside a huge-page, in crossbar coordinates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CellAddr {
    /// Crossbar index within the page.
    pub xbar: usize,
    /// Row within the crossbar.
    pub row: usize,
    /// Bit column of the first bit of the addressed byte (0..512).
    pub col: usize,
}

/// Bit-field description: (name, shift, width).
pub type Field = (&'static str, u32, u32);

/// The Fig. 3 physical-address ↔ crossbar-cell mapping.
#[derive(Clone, Debug)]
pub struct AddressMap {
    page_bits: u32,
    xbar_lo_shift: u32,
    xbar_lo_bits: u32,
    unit_shift: u32,
    unit_bits: u32,
    row_shift: u32,
    row_bits: u32,
    xbar_hi_shift: u32,
    xbar_hi_bits: u32,
    read_unit_bits: u32, // bits fetched per crossbar per access (16)
}

impl AddressMap {
    /// The paper's configuration: 1 GB pages, 1024x512 crossbars, 16-bit
    /// crossbar reads, 64 B cache lines touching 32 crossbars.
    pub fn paper_default() -> Self {
        AddressMap {
            page_bits: 30,
            xbar_lo_shift: 1,
            xbar_lo_bits: 5,
            unit_shift: 6,
            unit_bits: 5,
            row_shift: 11,
            row_bits: 10,
            xbar_hi_shift: 21,
            xbar_hi_bits: 9,
            read_unit_bits: 16,
        }
    }

    /// Derive a map for arbitrary geometry (rows/cols must be powers of 2).
    pub fn for_geometry(page_bytes: u64, rows: usize, cols: usize, read_bits: usize) -> Self {
        assert!(rows.is_power_of_two() && cols.is_power_of_two());
        assert!(page_bytes.is_power_of_two());
        let page_bits = page_bytes.trailing_zeros();
        let unit_bytes_bits = (read_bits / 8).trailing_zeros(); // bytes within unit
        let units = cols / read_bits;
        let unit_bits = units.trailing_zeros();
        let row_bits = rows.trailing_zeros();
        let xbar_bits =
            page_bits - unit_bytes_bits - unit_bits - row_bits;
        let xbar_lo_bits = xbar_bits.min(5);
        let xbar_hi_bits = xbar_bits - xbar_lo_bits;
        let xbar_lo_shift = unit_bytes_bits;
        let unit_shift = xbar_lo_shift + xbar_lo_bits;
        let row_shift = unit_shift + unit_bits;
        let xbar_hi_shift = row_shift + row_bits;
        AddressMap {
            page_bits,
            xbar_lo_shift,
            xbar_lo_bits,
            unit_shift,
            unit_bits,
            row_shift,
            row_bits,
            xbar_hi_shift,
            xbar_hi_bits,
            read_unit_bits: read_bits as u32,
        }
    }

    /// Huge-page size in bytes.
    pub fn page_bytes(&self) -> u64 {
        1u64 << self.page_bits
    }

    /// Crossbars addressed within one page.
    pub fn xbars_per_page(&self) -> usize {
        1usize << (self.xbar_lo_bits + self.xbar_hi_bits)
    }

    /// Rows per crossbar.
    pub fn rows(&self) -> usize {
        1usize << self.row_bits
    }

    /// Crossbars touched by one cache-line (64 B) access.
    pub fn xbars_per_line(&self) -> usize {
        1usize << self.xbar_lo_bits
    }

    fn mask(bits: u32) -> u64 {
        (1u64 << bits) - 1
    }

    /// Decode a page offset into crossbar coordinates.
    pub fn decode(&self, offset: u64) -> CellAddr {
        debug_assert!(offset < self.page_bytes());
        let byte = offset & Self::mask(self.xbar_lo_shift);
        let xlo = (offset >> self.xbar_lo_shift) & Self::mask(self.xbar_lo_bits);
        let unit = (offset >> self.unit_shift) & Self::mask(self.unit_bits);
        let row = (offset >> self.row_shift) & Self::mask(self.row_bits);
        let xhi = (offset >> self.xbar_hi_shift) & Self::mask(self.xbar_hi_bits);
        CellAddr {
            xbar: ((xhi << self.xbar_lo_bits) | xlo) as usize,
            row: row as usize,
            col: (unit as usize) * self.read_unit_bits as usize + (byte as usize) * 8,
        }
    }

    /// Encode crossbar coordinates into a page offset (col in bits, must be
    /// byte-aligned).
    pub fn encode(&self, xbar: usize, row: usize, col: usize) -> u64 {
        debug_assert_eq!(col % 8, 0, "addressable cells are byte-aligned");
        let unit = (col / self.read_unit_bits as usize) as u64;
        let byte = ((col % self.read_unit_bits as usize) / 8) as u64;
        let xlo = (xbar as u64) & Self::mask(self.xbar_lo_bits);
        let xhi = (xbar as u64) >> self.xbar_lo_bits;
        byte | (xlo << self.xbar_lo_shift)
            | (unit << self.unit_shift)
            | ((row as u64) << self.row_shift)
            | (xhi << self.xbar_hi_shift)
    }

    /// Offset for a (row, column) cell with crossbar index 0 — PIM requests
    /// target all crossbars of a page, so the crossbar field is ignored
    /// (paper §3.1 "PIM requests").
    pub fn encode_cell_offset(&self, row: usize, col: usize) -> u64 {
        // PIM request result columns need bit, not byte, granularity: use
        // the unit field plus the byte bit for col/8; sub-byte position is
        // carried redundantly in the payload.
        self.encode(0, row, col & !7)
    }

    /// Inverse of [`encode_cell_offset`]: (row, col) with col rounded to
    /// its byte boundary; the payload supplies the exact bit.
    pub fn decode_cell_offset(&self, offset: u64) -> (usize, usize) {
        let c = self.decode(offset);
        (c.row, c.col)
    }

    /// Field layout for display (Fig. 3 reproduction).
    pub fn fields(&self) -> Vec<Field> {
        vec![
            ("byte-in-unit", 0, self.xbar_lo_shift),
            ("xbar-lo", self.xbar_lo_shift, self.xbar_lo_bits),
            ("unit-in-row", self.unit_shift, self.unit_bits),
            ("row", self.row_shift, self.row_bits),
            ("xbar-hi", self.xbar_hi_shift, self.xbar_hi_bits),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn paper_default_geometry() {
        let m = AddressMap::paper_default();
        assert_eq!(m.page_bytes(), 1 << 30);
        assert_eq!(m.xbars_per_page(), 16384);
        assert_eq!(m.rows(), 1024);
        assert_eq!(m.xbars_per_line(), 32);
    }

    #[test]
    fn for_geometry_matches_paper_default() {
        let m = AddressMap::for_geometry(1 << 30, 1024, 512, 16);
        let d = AddressMap::paper_default();
        assert_eq!(m.xbars_per_page(), d.xbars_per_page());
        assert_eq!(m.rows(), d.rows());
        assert_eq!(m.xbars_per_line(), d.xbars_per_line());
    }

    #[test]
    fn encode_decode_bijective_property() {
        let m = AddressMap::paper_default();
        check("addr-roundtrip", 500, |g| {
            let xbar = g.usize(0, 16383);
            let row = g.usize(0, 1023);
            let col = g.usize(0, 63) * 8; // byte-aligned bit column
            let off = m.encode(xbar, row, col);
            assert!(off < m.page_bytes());
            let c = m.decode(off);
            assert_eq!((c.xbar, c.row, c.col), (xbar, row, col));
        });
    }

    #[test]
    fn offsets_are_unique() {
        // all (xbar, row, col) combos at coarse stride map to distinct offsets
        let m = AddressMap::for_geometry(1 << 20, 64, 128, 16);
        let mut seen = std::collections::HashSet::new();
        for xbar in 0..m.xbars_per_page() {
            for row in (0..64).step_by(7) {
                for col in (0..128).step_by(8) {
                    assert!(seen.insert(m.encode(xbar, row, col)));
                }
            }
        }
    }

    #[test]
    fn cache_line_touches_32_crossbars_16_bits_each() {
        let m = AddressMap::paper_default();
        let base = m.encode(0, 37, 16); // start of unit 1, row 37
        let mut xbars = std::collections::HashSet::new();
        for b in 0..64u64 {
            let c = m.decode(base + b);
            assert_eq!(c.row, 37);
            xbars.insert(c.xbar);
        }
        assert_eq!(xbars.len(), 32);
    }

    #[test]
    fn fields_cover_page_bits_disjointly() {
        let m = AddressMap::paper_default();
        let mut covered = 0u64;
        for (_, shift, width) in m.fields() {
            let mask = ((1u64 << width) - 1) << shift;
            assert_eq!(covered & mask, 0, "field overlap");
            covered |= mask;
        }
        assert_eq!(covered, (1u64 << 30) - 1);
    }
}
