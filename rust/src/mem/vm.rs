//! Huge-page virtual memory for PIM data (paper §3.1).
//!
//! PIM operations are confined to a single huge-page; a data structure
//! spanning pages receives one PIM request per page. The allocator assigns
//! each huge-page to a single bank of a single module (paper §3.2),
//! spreading consecutive pages across modules first (maximizing channel
//! parallelism), then across banks.

use crate::config::SystemConfig;
use crate::pim::module::PageLoc;

/// One allocated huge-page.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HugePage {
    /// Physical placement (module, bank, dense page id).
    pub loc: PageLoc,
    /// Virtual base address of the page.
    pub vbase: u64,
}

/// Huge-page allocation failure: a PIM module ran out of pages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CapacityError {
    /// The module that could not supply another page.
    pub module: usize,
    /// Pages each module can hold (`module_capacity / page_bytes`).
    pub pages_per_module: u64,
}

impl std::fmt::Display for CapacityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PIM module {} exhausted ({} pages)",
            self.module, self.pages_per_module
        )
    }
}

impl std::error::Error for CapacityError {}

/// System-wide huge-page allocator.
pub struct PageAllocator {
    modules: usize,
    banks: usize,
    pages_per_module: u64,
    next_page: usize,
    next_vbase: u64,
    page_bytes: u64,
    allocated_per_module: Vec<u64>,
}

impl PageAllocator {
    /// An empty allocator over the configured module geometry.
    pub fn new(cfg: &SystemConfig) -> Self {
        PageAllocator {
            modules: cfg.pim_modules,
            banks: cfg.banks_per_module,
            pages_per_module: cfg.module_capacity / cfg.page_bytes,
            next_page: 0,
            next_vbase: 0x4000_0000_0000, // arbitrary PIM VA region base
            page_bytes: cfg.page_bytes,
            allocated_per_module: vec![0; cfg.pim_modules],
        }
    }

    /// Allocate `n` huge-pages for one data structure (relation).
    /// Returns an error when PIM capacity is exhausted.
    pub fn allocate(&mut self, n: usize) -> Result<Vec<HugePage>, CapacityError> {
        let mut pages = Vec::with_capacity(n);
        for _ in 0..n {
            // round-robin module, then bank within module
            let module = self.next_page % self.modules;
            if self.allocated_per_module[module] >= self.pages_per_module {
                return Err(CapacityError {
                    module,
                    pages_per_module: self.pages_per_module,
                });
            }
            let within = self.allocated_per_module[module];
            let bank = (within as usize) % self.banks;
            self.allocated_per_module[module] += 1;
            let page = HugePage {
                loc: PageLoc {
                    module,
                    bank,
                    page: self.next_page,
                },
                vbase: self.next_vbase,
            };
            self.next_page += 1;
            self.next_vbase += self.page_bytes;
            pages.push(page);
        }
        Ok(pages)
    }

    /// Total pages handed out so far.
    pub fn pages_allocated(&self) -> usize {
        self.next_page
    }

    /// Pages held by the busiest module (Fig. 14 theoretical peak input).
    pub fn max_pages_in_module(&self) -> u64 {
        self.allocated_per_module.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_spread_across_modules_first() {
        let cfg = SystemConfig::default();
        let mut a = PageAllocator::new(&cfg);
        let pages = a.allocate(16).unwrap();
        let mods: std::collections::HashSet<_> =
            pages.iter().map(|p| p.loc.module).collect();
        assert_eq!(mods.len(), cfg.pim_modules); // all 8 modules used
        // two pages per module land on different banks
        assert_ne!(pages[0].loc.bank, pages[8].loc.bank);
    }

    #[test]
    fn vbase_unique_and_page_aligned() {
        let cfg = SystemConfig::default();
        let mut a = PageAllocator::new(&cfg);
        let pages = a.allocate(10).unwrap();
        let mut seen = std::collections::HashSet::new();
        for p in &pages {
            assert_eq!(p.vbase % cfg.page_bytes, 0);
            assert!(seen.insert(p.vbase));
        }
    }

    #[test]
    fn capacity_exhaustion_errors() {
        let mut cfg = SystemConfig::default();
        cfg.pim_modules = 1;
        cfg.module_capacity = 4 << 30; // 4 pages
        let mut a = PageAllocator::new(&cfg);
        assert!(a.allocate(4).is_ok());
        assert!(a.allocate(1).is_err());
    }

    #[test]
    fn max_pages_in_module_balanced() {
        let cfg = SystemConfig::default();
        let mut a = PageAllocator::new(&cfg);
        a.allocate(20).unwrap();
        // 20 pages over 8 modules: max is ceil(20/8) = 3
        assert_eq!(a.max_pages_in_module(), 3);
    }
}
