//! Host memory substrate: physical-address/cell mapping, huge-page virtual
//! memory, cache hierarchy, and DRAM main memory models.

pub mod addr;
pub mod cache;
pub mod dram;
pub mod vm;
