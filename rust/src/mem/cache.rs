//! Two-level set-associative cache model (paper Table 3: private 64 KB
//! 4-way L1, shared 8 MB 16-way L2/LLC, 64 B blocks, LRU).
//!
//! Trace-driven: the baseline executor feeds every attribute access
//! through this model; LLC misses are the paper's headline proxy for
//! memory reads (Fig. 8 reports the LLC-miss reduction of PIMDB vs the
//! baseline).

use crate::config::SystemConfig;

/// Where an access was satisfied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Hit in the private L1.
    L1,
    /// Hit in the shared L2 (LLC).
    L2,
    /// LLC miss, served by memory.
    Memory,
}

/// Access counters of one simulated cache hierarchy.
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    /// Total accesses.
    pub accesses: u64,
    /// Accesses satisfied by L1.
    pub l1_hits: u64,
    /// Accesses satisfied by L2.
    pub l2_hits: u64,
    /// Accesses that missed the LLC.
    pub llc_misses: u64,
    /// Dirty evictions written back.
    pub writebacks: u64,
}

struct SetAssoc {
    sets: usize,
    ways: usize,
    block_bits: u32,
    /// tags[set][way]; LRU order: way 0 = MRU after touch (we rotate).
    tags: Vec<Vec<u64>>,
    dirty: Vec<Vec<bool>>,
}

const INVALID: u64 = u64::MAX;

impl SetAssoc {
    fn new(bytes: usize, ways: usize, block: usize) -> Self {
        let sets = (bytes / block / ways).max(1);
        assert!(sets.is_power_of_two(), "cache sets must be a power of two");
        SetAssoc {
            sets,
            ways,
            block_bits: block.trailing_zeros(),
            tags: vec![vec![INVALID; ways]; sets],
            dirty: vec![vec![false; ways]; sets],
        }
    }

    fn index_tag(&self, addr: u64) -> (usize, u64) {
        let blk = addr >> self.block_bits;
        ((blk as usize) & (self.sets - 1), blk)
    }

    /// Invalidate a block if present (clflush); returns whether it held
    /// dirty data.
    fn invalidate(&mut self, addr: u64) -> bool {
        let (set, tag) = self.index_tag(addr);
        let ways = &mut self.tags[set];
        if let Some(pos) = ways.iter().position(|&t| t == tag) {
            let dirty = self.dirty[set][pos];
            // rotate the victim to LRU and invalidate it
            ways[pos..].rotate_left(1);
            self.dirty[set][pos..].rotate_left(1);
            let last = self.ways - 1;
            ways[last] = INVALID;
            self.dirty[set][last] = false;
            dirty
        } else {
            false
        }
    }

    /// Touch a block; returns true on hit. On miss, installs the block and
    /// returns the evicted dirty block tag if any.
    fn access(&mut self, addr: u64, write: bool) -> (bool, Option<u64>) {
        let (set, tag) = self.index_tag(addr);
        let ways = &mut self.tags[set];
        if let Some(pos) = ways.iter().position(|&t| t == tag) {
            // move to MRU
            ways[..=pos].rotate_right(1);
            self.dirty[set][..=pos].rotate_right(1);
            if write {
                self.dirty[set][0] = true;
            }
            return (true, None);
        }
        // miss: evict LRU (last way)
        let evicted_tag = ways[self.ways - 1];
        let evicted_dirty = self.dirty[set][self.ways - 1];
        ways.rotate_right(1);
        self.dirty[set].rotate_right(1);
        ways[0] = tag;
        self.dirty[set][0] = write;
        let wb = (evicted_tag != INVALID && evicted_dirty).then_some(evicted_tag);
        (false, wb)
    }
}

/// One thread's view: private L1 + a slice of the shared L2 (threads
/// stream disjoint relation partitions, so partitioning the LLC capacity
/// approximates sharing without cross-thread state).
pub struct CacheSim {
    l1: SetAssoc,
    l2: SetAssoc,
    /// Access counters (read them after driving the accesses).
    pub stats: CacheStats,
}

impl CacheSim {
    /// A hierarchy with the whole L2 owned by this thread.
    pub fn new(cfg: &SystemConfig) -> Self {
        Self::with_l2_share(cfg, 1)
    }

    /// `l2_share`: number of threads splitting the LLC.
    pub fn with_l2_share(cfg: &SystemConfig, l2_share: usize) -> Self {
        CacheSim {
            l1: SetAssoc::new(cfg.l1_bytes, cfg.l1_ways, cfg.cache_block),
            l2: SetAssoc::new(
                (cfg.l2_bytes / l2_share.max(1)).max(cfg.cache_block * cfg.l2_ways),
                cfg.l2_ways,
                cfg.cache_block,
            ),
            stats: CacheStats::default(),
        }
    }

    /// Access one byte address; returns the level that served it.
    pub fn access(&mut self, addr: u64, write: bool) -> Level {
        self.stats.accesses += 1;
        let (hit1, _) = self.l1.access(addr, write);
        if hit1 {
            self.stats.l1_hits += 1;
            return Level::L1;
        }
        let (hit2, wb) = self.l2.access(addr, write);
        if wb.is_some() {
            self.stats.writebacks += 1;
        }
        if hit2 {
            self.stats.l2_hits += 1;
            Level::L2
        } else {
            self.stats.llc_misses += 1;
            Level::Memory
        }
    }

    /// `clflush` of a `len`-byte range: every covered line is invalidated
    /// in both levels — the paper's §3.1 programming model for stores to
    /// PIM memory (PIM data must not stay cached). A line that was dirty
    /// in either level is written back to memory whether or not the
    /// cache would have evicted it; clean or absent lines flush for
    /// free. Returns the lines written back (each counts one writeback).
    pub fn flush_range(&mut self, addr: u64, len: usize) -> u64 {
        let block = 1u64 << self.l1.block_bits;
        let first = addr & !(block - 1);
        let last = (addr + len.max(1) as u64 - 1) & !(block - 1);
        let mut written_back = 0u64;
        let mut a = first;
        loop {
            // invalidate both levels; the line's data travels once
            let dirty = self.l1.invalidate(a) | self.l2.invalidate(a);
            if dirty {
                self.stats.writebacks += 1;
                written_back += 1;
            }
            if a == last {
                break;
            }
            a += block;
        }
        written_back
    }

    /// Access a `len`-byte field starting at `addr` (touches each block).
    pub fn access_range(&mut self, addr: u64, len: usize, write: bool) -> u64 {
        let block = 1u64 << self.l1.block_bits;
        let first = addr & !(block - 1);
        let last = (addr + len.max(1) as u64 - 1) & !(block - 1);
        let mut misses = 0;
        let mut a = first;
        loop {
            if self.access(a, write) == Level::Memory {
                misses += 1;
            }
            if a == last {
                break;
            }
            a += block;
        }
        misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn repeat_access_hits_l1() {
        let mut c = CacheSim::new(&cfg());
        assert_eq!(c.access(0x1000, false), Level::Memory);
        assert_eq!(c.access(0x1000, false), Level::L1);
        assert_eq!(c.access(0x1010, false), Level::L1); // same block
        assert_eq!(c.stats.llc_misses, 1);
    }

    #[test]
    fn l1_eviction_falls_to_l2() {
        let cfg = cfg();
        let mut c = CacheSim::new(&cfg);
        // fill one L1 set beyond its ways: same set index, different tags
        let sets = cfg.l1_bytes / cfg.cache_block / cfg.l1_ways;
        let stride = (sets * cfg.cache_block) as u64;
        for i in 0..(cfg.l1_ways as u64 + 1) {
            c.access(i * stride, false);
        }
        // first block evicted from L1, still in L2
        assert_eq!(c.access(0, false), Level::L2);
    }

    #[test]
    fn streaming_misses_once_per_block() {
        let cfg = cfg();
        let mut c = CacheSim::new(&cfg);
        let n_blocks = 1000u64;
        for b in 0..n_blocks {
            for byte in 0..4 {
                c.access(b * 64 + byte * 16, false);
            }
        }
        assert_eq!(c.stats.llc_misses, n_blocks);
    }

    #[test]
    fn working_set_larger_than_llc_thrashes() {
        let cfg = cfg();
        let mut c = CacheSim::new(&cfg);
        let blocks = (2 * cfg.l2_bytes / cfg.cache_block) as u64;
        for pass in 0..2 {
            for b in 0..blocks {
                c.access(b * 64, false);
                let _ = pass;
            }
        }
        // second pass misses again (LRU streaming)
        assert!(c.stats.llc_misses > blocks + blocks / 2);
    }

    #[test]
    fn dirty_eviction_counts_writeback() {
        let cfg = cfg();
        let mut c = CacheSim::new(&cfg);
        let l2_sets = cfg.l2_bytes / cfg.cache_block / cfg.l2_ways;
        let stride = (l2_sets * cfg.cache_block) as u64;
        c.access(0, true); // dirty in both levels
        for i in 1..(cfg.l2_ways as u64 + 2) {
            c.access(i * stride, false);
        }
        assert!(c.stats.writebacks >= 1);
    }

    #[test]
    fn flush_evicts_from_both_levels_and_counts_dirty_writebacks() {
        let mut c = CacheSim::new(&cfg());
        c.access(0x2000, true); // resident + dirty
        assert_eq!(c.access(0x2000, false), Level::L1);
        let wb_before = c.stats.writebacks;
        // 8 bytes straddling a line boundary: the dirty resident line is
        // written back; the uncached neighbour flushes for free
        assert_eq!(c.flush_range(0x2000 + 60, 8), 1);
        assert_eq!(c.stats.writebacks, wb_before + 1);
        // the flushed line is gone from both levels: the next read
        // goes to memory (PIM data must not stay cached)
        assert_eq!(c.access(0x2000, false), Level::Memory);
        // flushing a clean (read-only) line writes nothing back
        c.access(0x4000, false);
        assert_eq!(c.flush_range(0x4000, 8), 0);
        assert_eq!(c.access(0x4000, false), Level::Memory);
    }

    #[test]
    fn access_range_spans_blocks() {
        let mut c = CacheSim::new(&cfg());
        // 8 bytes straddling a 64 B boundary -> two blocks
        let misses = c.access_range(60, 8, false);
        assert_eq!(misses, 2);
    }

    #[test]
    fn hits_plus_misses_equals_accesses_property() {
        check("cache-conservation", 20, |g| {
            let cfg = SystemConfig::default();
            let mut c = CacheSim::new(&cfg);
            for _ in 0..2000 {
                let addr = g.u64(0, 1 << 24) & !3;
                c.access(addr, g.bool());
            }
            let s = &c.stats;
            assert_eq!(s.accesses, s.l1_hits + s.l2_hits + s.llc_misses);
        });
    }
}
