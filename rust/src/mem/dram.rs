//! DDR4 main-memory timing/energy model (paper Table 3: 64 GB DDR4-2400,
//! two channels; energy via the gem5 DRAM power model, which we substitute
//! with per-byte transfer energy + standby power).

use crate::config::SystemConfig;

/// Traffic counters of the DRAM model.
#[derive(Clone, Debug, Default)]
pub struct DramStats {
    /// Bytes read from DRAM.
    pub bytes_read: u64,
    /// Bytes written to DRAM.
    pub bytes_written: u64,
}

/// Bandwidth/latency/energy model of the host's DDR4 main memory.
pub struct DramModel {
    bw_bps: f64,
    latency_ns: u64,
    energy_pj_per_byte: f64,
    standby_w: f64,
    /// Traffic counters (updated by `record_read`/`record_write`).
    pub stats: DramStats,
}

impl DramModel {
    /// A model with Table 3's DDR4 parameters.
    pub fn new(cfg: &SystemConfig) -> Self {
        DramModel {
            bw_bps: cfg.dram_bw_bps,
            latency_ns: cfg.dram_latency_ns,
            energy_pj_per_byte: cfg.dram_energy_pj_per_byte,
            standby_w: cfg.dram_standby_w,
            stats: DramStats::default(),
        }
    }

    /// Account `bytes` of read traffic.
    pub fn record_read(&mut self, bytes: u64) {
        self.stats.bytes_read += bytes;
    }

    /// Account `bytes` of write traffic.
    pub fn record_write(&mut self, bytes: u64) {
        self.stats.bytes_written += bytes;
    }

    /// Time to stream `bytes` at peak bandwidth (s).
    pub fn stream_time_s(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bw_bps
    }

    /// Latency of one demand miss (s).
    pub fn miss_latency_s(&self) -> f64 {
        self.latency_ns as f64 * 1e-9
    }

    /// Dynamic transfer energy so far (pJ).
    pub fn dynamic_energy_pj(&self) -> f64 {
        (self.stats.bytes_read + self.stats.bytes_written) as f64 * self.energy_pj_per_byte
    }

    /// Standby/background energy over a span (pJ).
    pub fn standby_energy_pj(&self, span_s: f64) -> f64 {
        self.standby_w * span_s * 1e12
    }

    /// Total energy for a run of `span_s` (pJ).
    pub fn total_energy_pj(&self, span_s: f64) -> f64 {
        self.dynamic_energy_pj() + self.standby_energy_pj(span_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_time_matches_bandwidth() {
        let m = DramModel::new(&SystemConfig::default());
        // 38.4 GB at 38.4 GB/s = 1 s
        let t = m.stream_time_s(38_400_000_000);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn energy_accumulates() {
        let mut m = DramModel::new(&SystemConfig::default());
        m.record_read(1000);
        m.record_write(500);
        assert!((m.dynamic_energy_pj() - 1500.0 * 20.0).abs() < 1e-9);
        // standby dominates short transfers over long spans
        assert!(m.standby_energy_pj(1.0) > m.dynamic_energy_pj());
    }
}
