//! Sharded, parallel execution of compiled PIM programs.
//!
//! The paper's performance model assumes thousands of crossbars execute
//! each PIM request in lockstep; the functional engine interprets those
//! crossbars on the host, where they are *embarrassingly parallel*: no
//! instruction reads or writes state outside its own crossbar
//! ([`XbarState`]). This module splits a program's crossbar batch into
//! contiguous **shards** and executes shards concurrently on host worker
//! threads, then merges the per-shard outputs back into crossbar order.
//!
//! Determinism: a shard's outputs depend only on its own crossbars, and
//! the merge reassembles them in `(program, shard)` order, so the result
//! is bit-identical to the serial interpreter for every shard count and
//! thread count (asserted by `tests/prop_engine.rs` and the integration
//! equivalence suite).
//!
//! The same plan drives both functional backends: native shards run
//! [`engine::exec_steps_native`], PJRT shards run
//! [`crate::runtime::exec_steps_pjrt`] (each worker thread lazily
//! initializes its own thread-local PJRT runtime), keeping the two
//! engines differential-testable at any parallelism.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::config::SystemConfig;
use crate::exec::engine::{self, ExecOutputs, XbarState};
use crate::exec::pimdb::EngineKind;
use crate::exec::ExecError;
use crate::query::compiler::Step;

/// Shards per worker beyond 1x: partial tail shards and relation-size
/// imbalance smooth out when workers can steal more than one shard each.
pub const SHARD_OVERSUB: usize = 2;

/// How a query's compiled programs split into shards and onto workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecPlan {
    /// Host worker threads executing shards (>= 1).
    pub parallelism: usize,
    /// Target shard count per program (>= 1).
    pub shards_per_program: usize,
}

impl ExecPlan {
    /// Serial plan: one shard, one worker — the reference path.
    pub fn serial() -> ExecPlan {
        ExecPlan {
            parallelism: 1,
            shards_per_program: 1,
        }
    }

    /// Plan for `parallelism` workers (0 = auto-detect host cores).
    pub fn with_parallelism(parallelism: usize) -> ExecPlan {
        let p = resolve_parallelism(parallelism);
        ExecPlan {
            parallelism: p,
            shards_per_program: if p <= 1 { 1 } else { p * SHARD_OVERSUB },
        }
    }

    /// Plan from the config's `parallelism` knob.
    pub fn for_config(cfg: &SystemConfig) -> ExecPlan {
        ExecPlan::with_parallelism(cfg.parallelism)
    }

    /// Crossbars per shard for a program over `n_xbars` crossbars.
    pub fn shard_len(&self, n_xbars: usize) -> usize {
        n_xbars.div_ceil(self.shards_per_program.max(1)).max(1)
    }
}

/// Resolve the config value: 0 = one worker per available host core.
pub fn resolve_parallelism(parallelism: usize) -> usize {
    if parallelism == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        parallelism
    }
}

/// One unit of parallel work: a contiguous crossbar range of one program.
pub struct ShardTask<'a> {
    /// Program id (dense, `0..n_programs`).
    pub key: usize,
    /// Shard index within the program (merge order).
    pub shard: usize,
    /// The shard's contiguous crossbar states.
    pub states: &'a mut [XbarState],
    /// The program's compiled instruction steps (shared by all shards).
    pub steps: &'a [Step],
    /// Column holding the final filter mask.
    pub mask_col: usize,
    /// Functional backend interpreting the steps.
    pub engine: EngineKind,
}

fn run_one(t: ShardTask<'_>) -> Result<ExecOutputs, ExecError> {
    match t.engine {
        EngineKind::Native => Ok(engine::exec_steps_native(t.states, t.steps, t.mask_col)),
        EngineKind::Pjrt => crate::runtime::exec_steps_pjrt(t.states, t.steps, t.mask_col)
            .map_err(|msg| ExecError::Backend {
                engine: "pjrt",
                msg,
            }),
    }
}

/// Execute shard tasks over `parallelism` workers and merge per program.
///
/// Workers pull tasks from a shared queue (relation sizes differ wildly —
/// LINEITEM is ~60x SUPPLIER — so static assignment would idle threads).
/// Merging concatenates shard outputs in `(key, shard)` order, restoring
/// exactly the serial engine's per-crossbar order.
pub fn run_tasks(
    tasks: Vec<ShardTask<'_>>,
    n_programs: usize,
    parallelism: usize,
) -> Result<Vec<ExecOutputs>, ExecError> {
    let workers = parallelism.min(tasks.len()).max(1);
    let mut partials: Vec<(usize, usize, ExecOutputs)> = Vec::with_capacity(tasks.len());
    if workers == 1 {
        for t in tasks {
            let (key, shard) = (t.key, t.shard);
            partials.push((key, shard, run_one(t)?));
        }
    } else {
        let queue = Mutex::new(VecDeque::from(tasks));
        let done = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let next = queue.lock().unwrap().pop_front();
                    let Some(t) = next else { break };
                    let (key, shard) = (t.key, t.shard);
                    let r = run_one(t);
                    done.lock().unwrap().push((key, shard, r));
                });
            }
        });
        for (key, shard, r) in done.into_inner().unwrap() {
            partials.push((key, shard, r?));
        }
    }
    partials.sort_by_key(|&(key, shard, _)| (key, shard));

    let mut merged = vec![ExecOutputs::default(); n_programs];
    let mut seen = vec![false; n_programs];
    for (key, _shard, part) in partials {
        if !seen[key] {
            merged[key] = part;
            seen[key] = true;
        } else {
            let out = &mut merged[key];
            debug_assert_eq!(out.reduces.len(), part.reduces.len());
            for (dst, src) in out.reduces.iter_mut().zip(part.reduces) {
                dst.extend(src);
            }
            out.mask_counts.extend(part.mask_counts);
            out.shards_skipped += part.shards_skipped;
            out.steps_short_circuited += part.steps_short_circuited;
        }
    }
    Ok(merged)
}

/// Append one program's shard tasks to `tasks` — the single chunking
/// rule shared by [`exec_steps_sharded`] and the batched wave path in
/// [`crate::exec::pimdb::PimSession::run_queries`], so shard geometry
/// cannot silently diverge between them.
pub fn push_shard_tasks<'a>(
    tasks: &mut Vec<ShardTask<'a>>,
    key: usize,
    states: &'a mut [XbarState],
    steps: &'a [Step],
    mask_col: usize,
    engine: EngineKind,
    plan: &ExecPlan,
) {
    let shard_len = plan.shard_len(states.len());
    for (shard, chunk) in states.chunks_mut(shard_len).enumerate() {
        tasks.push(ShardTask {
            key,
            shard,
            states: chunk,
            steps,
            mask_col,
            engine,
        });
    }
}

/// Run one program over a crossbar batch, sharded per `plan`.
pub fn exec_steps_sharded(
    states: &mut [XbarState],
    steps: &[Step],
    mask_col: usize,
    engine: EngineKind,
    plan: &ExecPlan,
) -> Result<ExecOutputs, ExecError> {
    if states.is_empty() {
        // keep the output shape identical to the serial interpreter
        // (n_reduces empty per-crossbar vectors, not an empty `reduces`)
        return Ok(engine::exec_steps_native(states, steps, mask_col));
    }
    let mut tasks = Vec::new();
    push_shard_tasks(&mut tasks, 0, states, steps, mask_col, engine, plan);
    let mut merged = run_tasks(tasks, 1, plan.parallelism)?;
    Ok(merged.pop().expect("one program"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::endurance::OpCategory;
    use crate::pim::isa::{ColRange, Opcode, PimInstruction};
    use crate::util::bits::WORDS;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn step(instr: PimInstruction) -> Step {
        Step {
            instr,
            category: OpCategory::Filter,
        }
    }

    fn random_states(seed: u64, n: usize) -> Vec<XbarState> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut st = XbarState::new(160);
                for c in 0..32 {
                    for w in 0..WORDS {
                        st.planes[c][w] = rng.next_u64();
                    }
                }
                st
            })
            .collect()
    }

    fn program() -> Vec<Step> {
        vec![
            step(PimInstruction::with_imm(
                Opcode::LtImm,
                ColRange::new(0, 16),
                ColRange::new(100, 1),
                0x1234,
            )),
            step(PimInstruction::binary(
                Opcode::And,
                ColRange::new(0, 16),
                ColRange::new(100, 1),
                ColRange::new(110, 16),
            )),
            step(PimInstruction::unary(
                Opcode::ReduceSum,
                ColRange::new(110, 16),
                ColRange::new(110, 16),
            )),
            step(PimInstruction::unary(
                Opcode::ReduceMax,
                ColRange::new(110, 16),
                ColRange::new(110, 16),
            )),
        ]
    }

    #[test]
    fn sharded_matches_serial_across_plans() {
        check("plan-shard-equivalence", 12, |g| {
            let n = g.usize(1, 11);
            let seed = g.u64(0, 1 << 40);
            let steps = program();
            let mut serial = random_states(seed, n);
            let want = engine::exec_steps_native(&mut serial, &steps, 100);
            let plan = ExecPlan {
                parallelism: g.usize(1, 8),
                shards_per_program: g.usize(1, 16),
            };
            let mut sharded = random_states(seed, n);
            let got =
                exec_steps_sharded(&mut sharded, &steps, 100, EngineKind::Native, &plan).unwrap();
            assert_eq!(want.reduces, got.reduces, "plan {plan:?}");
            assert_eq!(want.mask_counts, got.mask_counts, "plan {plan:?}");
            for (a, b) in serial.iter().zip(&sharded) {
                assert_eq!(a.planes, b.planes);
            }
        });
    }

    #[test]
    fn run_tasks_merges_multiple_programs() {
        let steps_a = program();
        let steps_b = vec![step(PimInstruction::unary(
            Opcode::Set,
            ColRange::new(50, 1),
            ColRange::new(50, 1),
        ))];
        let mut sa = random_states(7, 5);
        let mut sb = random_states(8, 3);
        let mut want_a = sa.clone();
        let mut want_b = sb.clone();
        let wa = engine::exec_steps_native(&mut want_a, &steps_a, 100);
        let wb = engine::exec_steps_native(&mut want_b, &steps_b, 50);

        let mut tasks = Vec::new();
        for (shard, chunk) in sa.chunks_mut(2).enumerate() {
            tasks.push(ShardTask {
                key: 0,
                shard,
                states: chunk,
                steps: &steps_a,
                mask_col: 100,
                engine: EngineKind::Native,
            });
        }
        for (shard, chunk) in sb.chunks_mut(1).enumerate() {
            tasks.push(ShardTask {
                key: 1,
                shard,
                states: chunk,
                steps: &steps_b,
                mask_col: 50,
                engine: EngineKind::Native,
            });
        }
        let merged = run_tasks(tasks, 2, 4).unwrap();
        assert_eq!(merged[0].reduces, wa.reduces);
        assert_eq!(merged[0].mask_counts, wa.mask_counts);
        assert_eq!(merged[1].mask_counts, wb.mask_counts);
        assert!(merged[1].reduces.is_empty());
    }

    #[test]
    fn plan_geometry() {
        let p = ExecPlan::with_parallelism(4);
        assert_eq!(p.parallelism, 4);
        assert_eq!(p.shards_per_program, 4 * SHARD_OVERSUB);
        assert_eq!(p.shard_len(16), 2);
        assert_eq!(p.shard_len(1), 1);
        assert_eq!(ExecPlan::serial().shard_len(1000), 1000);
        assert_eq!(ExecPlan::with_parallelism(1).shards_per_program, 1);
        assert!(resolve_parallelism(0) >= 1);
        assert_eq!(resolve_parallelism(6), 6);
    }

    #[test]
    fn pjrt_tasks_error_cleanly_when_runtime_missing() {
        if crate::runtime::runtime_available() {
            return; // real runtime present: covered by differential tests
        }
        let mut sts = random_states(3, 2);
        let steps = program();
        let plan = ExecPlan::with_parallelism(2);
        let err =
            exec_steps_sharded(&mut sts, &steps, 100, EngineKind::Pjrt, &plan).unwrap_err();
        let ExecError::Backend { engine, msg } = err;
        assert_eq!(engine, "pjrt");
        assert!(!msg.is_empty());
    }
}
