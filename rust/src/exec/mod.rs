//! Execution engines: the PIMDB engine (functional crossbar interpreter +
//! full-system timing/energy simulation), the sharded parallel execution
//! plan that fans its crossbar work out over host threads, the always-on
//! shard pool serving concurrent snapshot readers, and the in-memory
//! column-store baseline the engine is compared against (paper
//! §5.4–§5.5).

pub mod baseline;
pub mod engine;
pub mod metrics;
pub mod pimdb;
pub mod plan;
pub(crate) mod pool;

/// Why the functional execution of a compiled program failed.
///
/// The native interpreter is total — it cannot fail — so in practice every
/// variant today wraps a backend-runtime condition (the PJRT client and its
/// AOT kernel artifacts live outside the type system). The enum exists so
/// those conditions travel as data to [`crate::error::PimdbError`] instead
/// of being flattened into strings mid-pipeline.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecError {
    /// A functional backend reported a runtime failure (e.g. the PJRT
    /// runtime or its kernel artifacts are missing or rejected a program).
    Backend {
        /// Which backend failed (`"native"` or `"pjrt"`).
        engine: &'static str,
        /// The backend's own description of the failure.
        msg: String,
    },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Backend { engine, msg } => {
                write!(f, "{engine} backend failed: {msg}")
            }
        }
    }
}

impl std::error::Error for ExecError {}
