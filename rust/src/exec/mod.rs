//! Execution engines: the PIMDB engine (functional crossbar interpreter +
//! full-system timing/energy simulation), the sharded parallel execution
//! plan that fans its crossbar work out over host threads, and the
//! in-memory column-store baseline it is compared against (paper
//! §5.4–§5.5).

pub mod baseline;
pub mod engine;
pub mod metrics;
pub mod pimdb;
pub mod plan;
