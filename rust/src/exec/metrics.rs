//! Execution reports: timing breakdown, memory-system counters, energy,
//! power, endurance — everything Figures 8–15 and Tables 5–6 consume.

use crate::pim::endurance::OpCategory;
use crate::pim::energy::EnergyLedger;

/// Per-category stateful-logic cycles on a single crossbar (Table 5).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CycleCounts {
    /// Predicate evaluation cycles.
    pub filter: u64,
    /// In-array arithmetic cycles (aggregate value expressions).
    pub arith: u64,
    /// Column-transform cycles (filter mask re-orientation for read-out).
    pub col_transform: u64,
    /// Column-parallel phase of the aggregation reduce.
    pub agg_col: u64,
    /// Row-sequential phase of the aggregation reduce.
    pub agg_row: u64,
}

impl CycleCounts {
    /// All categories summed.
    pub fn total(&self) -> u64 {
        self.filter + self.arith + self.col_transform + self.agg_col + self.agg_row
    }

    /// Add `cycles` to the bucket of `cat`.
    pub fn add(&mut self, cat: OpCategory, cycles: u64) {
        match cat {
            OpCategory::Filter => self.filter += cycles,
            OpCategory::Arith => self.arith += cycles,
            OpCategory::ColTransform => self.col_transform += cycles,
            OpCategory::AggCol => self.agg_col += cycles,
            OpCategory::AggRow => self.agg_row += cycles,
        }
    }

    /// Commutative merge: per-category sums are order-independent, so
    /// per-program counts combine to the same totals regardless of the
    /// order programs were executed or accounted in.
    pub fn merge(&mut self, other: &CycleCounts) {
        self.filter += other.filter;
        self.arith += other.arith;
        self.col_transform += other.col_transform;
        self.agg_col += other.agg_col;
        self.agg_row += other.agg_row;
    }
}

/// What the optimizer pass pipeline ([`crate::query::opt`]) did to a
/// query's compiled programs, summed over its relations (instruction and
/// cycle counts add; the cell peaks take the per-relation max, matching
/// Table 5's "Inter. cells" semantics). `before` is the compiler's naive
/// `-O0` stream, `after` the program the engine executed. At `-O0` the
/// two sides are equal.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OptSummary {
    /// Compiled instructions before passes.
    pub steps_before: u64,
    /// Instructions actually executed.
    pub steps_after: u64,
    /// Per-crossbar stateful-logic cycles before passes.
    pub cycles_before: u64,
    /// Per-crossbar cycles actually charged.
    pub cycles_after: u64,
    /// Peak intermediate cells before passes.
    pub inter_before: u64,
    /// Peak intermediate cells of the executed programs.
    pub inter_after: u64,
}

impl From<crate::query::opt::OptStats> for OptSummary {
    /// Fix a (possibly merged) per-program stats record into the report
    /// type — the single place the two representations meet.
    fn from(s: crate::query::opt::OptStats) -> OptSummary {
        OptSummary {
            steps_before: s.steps_before as u64,
            steps_after: s.steps_after as u64,
            cycles_before: s.cycles_before,
            cycles_after: s.cycles_after,
            inter_before: s.inter_before as u64,
            inter_after: s.inter_after as u64,
        }
    }
}

/// Plan-cache hit/miss counters of the [`crate::api::Pimdb`] handle that
/// executed the query, snapshotted at execution time. Both stay zero on
/// the legacy `PimSession` path and on the baseline (neither has a plan
/// cache). `hits + misses` equals the number of `prepare` calls the
/// handle had served so far; `misses` equals the number of compilations.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheCounters {
    /// Prepares served from the cache (no compilation ran).
    pub hits: u64,
    /// Prepares that compiled and populated the cache.
    pub misses: u64,
}

/// Shared-scan cache counters of a [`crate::api::Pimdb`] handle: when
/// several prepared queries over one relation share an identical filter
/// prefix (same mask function, up to compute-column renaming — see
/// `query::opt::sharedscan`), the handle executes the prefix once and
/// replays the cached mask for the rest. Kept separate from
/// [`PlanCacheCounters`] — plan-cache accounting (`hits + misses` ==
/// prepares served) is pinned by tests and must not absorb execution-time
/// events.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SharedScanCounters {
    /// Executions that reused a cached scan mask (prefix skipped).
    pub hits: u64,
    /// Shareable executions that ran the full program and populated the
    /// per-relation mask cache.
    pub misses: u64,
    /// Times a relation's mask cache was dropped (DML mutation or poison
    /// recovery).
    pub invalidations: u64,
}

/// Metrics of one query execution (PIMDB or baseline), at the report SF.
#[derive(Clone, Debug, Default)]
pub struct QueryMetrics {
    /// End-to-end execution time (s) at the report scale factor.
    pub exec_time_s: f64,
    /// PIM computation-phase time (Fig. 9); zero for the baseline.
    pub pim_time_s: f64,
    /// Result read-out phase time (Fig. 9); zero for the baseline.
    pub read_time_s: f64,
    /// Host-side work outside the memory phases (spawn/join, combine).
    pub other_time_s: f64,
    /// LLC misses (Fig. 8's second axis).
    pub llc_misses: u64,
    /// Host core + uncore energy (pJ, Figs. 11–12).
    pub host_energy_pj: f64,
    /// Main-memory DRAM energy (pJ).
    pub dram_energy_pj: f64,
    /// PIM-side energy breakdown (logic/read/write/controller/IO).
    pub pim_energy: EnergyLedger,
    /// Per-crossbar cycle counts by category (Table 5).
    pub cycles: CycleCounts,
    /// Peak intermediate cells (Table 5).
    pub inter_cells: usize,
    /// Optimizer before/after instruction and cycle counts.
    pub opt: OptSummary,
    /// Plan-cache counters of the serving [`crate::api::Pimdb`] handle at
    /// execution time (zero on the legacy / baseline paths).
    pub plan_cache: PlanCacheCounters,
    /// Crossbars the executor never ran because the relation's zone maps
    /// proved the query's filter selects no live row there (statistics-
    /// driven shard pruning; zero on the legacy / baseline paths).
    pub shards_skipped: u64,
    /// Filter-prefix steps abandoned mid-program by the runtime all-zero
    /// mask short-circuit, summed over crossbars (zero on the legacy /
    /// baseline paths).
    pub steps_short_circuited: u64,
    /// Peak memory-chip power over the run (W, Fig. 14).
    pub peak_chip_w: f64,
    /// Highest windowed-average chip power (W, Fig. 14).
    pub avg_chip_w: f64,
    /// Theoretical worst-case chip power for this query's placement (W).
    pub theoretical_chip_w: f64,
    /// Hottest-cell writes per execution (Fig. 15, Table 6).
    pub ops_per_cell: f64,
    /// Endurance required to sustain 10 years of back-to-back runs.
    pub required_endurance_10yr: f64,
    /// Fraction of hottest-cell writes per op category (Table 6 order).
    pub endurance_breakdown: [f64; 5],
}

impl QueryMetrics {
    /// Host + DRAM + PIM energy (pJ).
    pub fn total_energy_pj(&self) -> f64 {
        self.host_energy_pj + self.dram_energy_pj + self.pim_energy.total_pj()
    }
}

/// Functional result of one query (for PIMDB-vs-baseline equivalence).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QueryOutput {
    /// Selected records per relation (filter results).
    pub selected: Vec<(&'static str, u64)>,
    /// Aggregate rows: (group label, values as (label, value)).
    pub groups: Vec<GroupOutput>,
}

/// One aggregate result row.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GroupOutput {
    /// Group-by key as (attribute, dictionary id); empty when ungrouped.
    pub key: Vec<(&'static str, u64)>,
    /// Aggregate values as (label, value), in declaration order.
    pub values: Vec<(&'static str, f64)>,
    /// Records contributing to this group.
    pub count: u64,
}

/// One DML statement's execution result: functional effect plus the
/// simulated cost of applying it ([`crate::api::Pimdb::execute_dml`]).
#[derive(Clone, Debug)]
pub struct DmlResult {
    /// Live rows the statement touched: rows inserted (1), updated, or
    /// deleted. Dead rows never count — the filter is ANDed with VALID.
    pub rows_affected: u64,
    /// Cell writes this statement added to the hottest crossbar row,
    /// per cell (same ops-per-cell unit as
    /// [`QueryMetrics::ops_per_cell`]); the per-row counters themselves
    /// accumulate monotonically in the relation's free-row map.
    pub wear_delta: f64,
    /// Simulated timing/energy/endurance of applying the statement.
    pub metrics: QueryMetrics,
}

/// One engine's full report.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Name of the executed query.
    pub query: &'static str,
    /// Simulated timing/energy/power/endurance metrics.
    pub metrics: QueryMetrics,
    /// Functional result (for cross-engine equivalence checks).
    pub output: QueryOutput,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_counts_accumulate_by_category() {
        let mut c = CycleCounts::default();
        c.add(OpCategory::Filter, 10);
        c.add(OpCategory::AggRow, 5);
        c.add(OpCategory::Filter, 1);
        assert_eq!(c.filter, 11);
        assert_eq!(c.agg_row, 5);
        assert_eq!(c.total(), 16);
    }

    #[test]
    fn merge_is_commutative() {
        let mut a = CycleCounts::default();
        a.add(OpCategory::Filter, 3);
        a.add(OpCategory::AggCol, 7);
        let mut b = CycleCounts::default();
        b.add(OpCategory::Arith, 5);
        b.add(OpCategory::AggCol, 1);
        let mut ab = a;
        ab.merge(&b);
        let mut ba = b;
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.total(), 16);
    }

    #[test]
    fn total_energy_sums_components() {
        let mut m = QueryMetrics::default();
        m.host_energy_pj = 1.0;
        m.dram_energy_pj = 2.0;
        m.pim_energy.logic_pj = 3.0;
        assert!((m.total_energy_pj() - 6.0).abs() < 1e-12);
    }
}
