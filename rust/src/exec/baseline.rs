//! Baseline: in-memory column-store query execution on the modelled host
//! (paper §5.5).
//!
//! The same query operations PIMDB executes are run as a host scan over
//! column-stored, identically-encoded relations: four threads each
//! traverse a quarter of the records, filtering with nested-if early exit
//! (conjunct order chosen offline by measured selectivity) and
//! aggregating selected records. Every attribute access is driven through
//! the L1/L2 cache model; timing comes from the analytic OoO core model;
//! counts are scaled from the simulated SF to the report SF (volumes are
//! linear in SF, and the caches stream either way).

use crate::config::SystemConfig;
use crate::db::dbgen::{Database, Relation};
use crate::db::schema;
use crate::exec::metrics::{DmlResult, GroupOutput, QueryMetrics, QueryOutput, RunReport};
use crate::host;
use crate::mem::cache::CacheSim;
use crate::mem::dram::DramModel;
use crate::query::ast::{AggKind, Dml, Pred, Query, QueryKind, RelQuery};

/// Decompose a filter into its top-level conjuncts (early-exit units).
fn conjuncts(p: &Pred) -> Vec<&Pred> {
    match p {
        Pred::And(ps) => ps.iter().flat_map(conjuncts).collect(),
        other => vec![other],
    }
}

/// Measured selectivity of a conjunct on a sample prefix (dead rows can
/// never pass, so they count as misses).
fn selectivity(rel: &Relation, p: &Pred, sample: usize) -> f64 {
    let n = rel.records.min(sample).max(1);
    let hits = (0..n)
        .filter(|&i| rel.live(i) && p.eval(&|name| rel.col(name)[i]))
        .count();
    hits as f64 / n as f64
}

/// Column virtual base addresses: distinct regions per (rel, column).
fn col_base(rel_idx: usize, col_idx: usize) -> u64 {
    0x1000_0000_0000 + ((rel_idx as u64) << 40) + ((col_idx as u64) << 34)
}

fn attr_bytes(rel: schema::RelId, name: &str) -> u64 {
    let bits = schema::attr(rel, name).map(|a| a.bits).unwrap_or(32);
    (bits as u64).div_ceil(8).max(1)
}

/// Execute `q` on the modelled host column store: functional result
/// plus analytic timing/energy at the report scale factor.
pub fn run_query(cfg: &SystemConfig, db: &Database, q: &Query) -> RunReport {
    let mut output = QueryOutput::default();
    let mut act = host::core::Activity::default();
    let mut dram = DramModel::new(cfg);
    let mut total_time = host::core::spawn_join_overhead_s(cfg, cfg.exec_threads);

    for (ri, rq) in q.rels.iter().enumerate() {
        let rel = db.rel(rq.rel);
        let scale = rq.rel.records_at_sf(cfg.report_sf) as f64 / rel.records.max(1) as f64;

        // order conjuncts by ascending selectivity (offline choice, §5.5)
        let mut parts: Vec<(&Pred, f64)> = conjuncts(&rq.filter)
            .into_iter()
            .map(|p| (p, selectivity(rel, p, 1000)))
            .collect();
        parts.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

        // per-conjunct attribute lists (accessed when the conjunct runs)
        let part_attrs: Vec<Vec<&'static str>> =
            parts.iter().map(|(p, _)| p.attrs()).collect();
        let agg_attrs: Vec<&'static str> = {
            let mut v: Vec<&'static str> = rq
                .aggregates
                .iter()
                .flat_map(|a| a.expr.attrs())
                .chain(rq.group_by.iter().copied())
                .collect();
            v.sort();
            v.dedup();
            v
        };

        // one cache per thread-equivalent; we scan once and divide by the
        // thread count afterwards (threads stream disjoint partitions)
        let mut cache = CacheSim::with_l2_share(cfg, cfg.exec_threads);
        let mut instr = 0u64;
        let mut selected = 0u64;
        use std::collections::BTreeMap;
        // key by dictionary values only (string-keyed compares showed up
        // in the profile); names are re-attached from group_by on output
        let mut groups: BTreeMap<Vec<u64>, GroupOutput> = BTreeMap::new();

        let col_index: BTreeMap<&str, usize> = rel
            .column_names()
            .iter()
            .enumerate()
            .map(|(i, n)| (*n, i))
            .collect();

        // resolve every referenced column to its slice once — name-keyed
        // lookup per record access was 12% of the end-to-end profile
        // (EXPERIMENTS.md §Perf)
        let resolved: Vec<(&'static str, &[u64])> = {
            let mut names: Vec<&'static str> = part_attrs
                .iter()
                .flatten()
                .copied()
                .chain(agg_attrs.iter().copied())
                .collect();
            names.sort();
            names.dedup();
            names.into_iter().map(|n| (n, rel.col(n))).collect()
        };
        let lookup = |name: &str, rec: usize| -> u64 {
            // static-str identity first: predicates and the resolved list
            // share the same literals, so this almost always hits without
            // a content compare
            for (n, s) in &resolved {
                if std::ptr::eq(n.as_ptr(), name.as_ptr()) {
                    return s[rec];
                }
            }
            for (n, s) in &resolved {
                if *n == name {
                    return s[rec];
                }
            }
            rel.col(name)[rec]
        };

        for rec in 0..rel.records {
            // dead rows (DML deletes / unreclaimed slots) are invisible:
            // the valid-bitmap test is the host-side twin of the PIM
            // engine's mask AND VALID
            if !rel.live(rec) {
                instr += 1; // bitmap test + branch
                continue;
            }
            let get = |name: &str| lookup(name, rec);
            let mut pass = true;
            for (pi, (p, _)) in parts.iter().enumerate() {
                // access this conjunct's attributes
                for a in &part_attrs[pi] {
                    let w = attr_bytes(rq.rel, a);
                    let addr = col_base(ri, col_index[*a]) + rec as u64 * w;
                    cache.access_range(addr, w as usize, false);
                    instr += 2;
                }
                instr += 2; // compare + branch
                if !p.eval(&get) {
                    pass = false;
                    break;
                }
            }
            if !pass {
                continue;
            }
            selected += 1;
            if q.kind == QueryKind::Full {
                for a in &agg_attrs {
                    let w = attr_bytes(rq.rel, a);
                    let addr = col_base(ri, col_index[*a]) + rec as u64 * w;
                    cache.access_range(addr, w as usize, false);
                    instr += 2;
                }
                let key: Vec<u64> = rq.group_by.iter().map(|&g| get(g)).collect();
                let entry = groups.entry(key.clone()).or_insert_with(|| GroupOutput {
                    key: rq.group_by.iter().copied().zip(key).collect(),
                    values: rq.aggregates.iter().map(|a| (a.label, 0.0)).collect(),
                    count: 0,
                });
                entry.count += 1;
                for (vi, agg) in rq.aggregates.iter().enumerate() {
                    let v = agg.expr.eval(&get) as f64;
                    match agg.kind {
                        AggKind::Sum | AggKind::Avg | AggKind::Count => {
                            entry.values[vi].1 += if agg.kind == AggKind::Count {
                                1.0
                            } else {
                                v
                            }
                        }
                        AggKind::Min => {
                            if entry.count == 1 || v < entry.values[vi].1 {
                                entry.values[vi].1 = v;
                            }
                        }
                        AggKind::Max => {
                            if entry.count == 1 || v > entry.values[vi].1 {
                                entry.values[vi].1 = v;
                            }
                        }
                    }
                    instr += 4;
                }
            }
        }

        // finalize averages; ungrouped aggregates always yield one row
        // (zero-valued when nothing selected), like the PIM engine
        let mut group_rows: Vec<GroupOutput> = groups.into_values().collect();
        if q.kind == QueryKind::Full && rq.group_by.is_empty() && group_rows.is_empty() {
            group_rows.push(GroupOutput {
                key: vec![],
                values: rq.aggregates.iter().map(|a| (a.label, 0.0)).collect(),
                count: 0,
            });
        }
        for g in &mut group_rows {
            for (vi, agg) in rq.aggregates.iter().enumerate() {
                if agg.kind == AggKind::Avg && g.count > 0 {
                    g.values[vi].1 /= g.count as f64;
                }
            }
        }
        output.selected.push((rq.rel.name(), selected));
        output.groups.extend(group_rows);

        // --- scale to report SF and fold into activity -------------------
        let s = &cache.stats;
        let misses = (s.llc_misses as f64 * scale) as u64;
        let bytes = misses * cfg.cache_block as u64;
        let per_thread = cfg.exec_threads as u64;
        let thread_act = host::core::Activity {
            instructions: ((instr as f64 * scale) as u64) / per_thread,
            l1_hits: ((s.l1_hits as f64 * scale) as u64) / per_thread,
            l2_hits: ((s.l2_hits as f64 * scale) as u64) / per_thread,
            llc_misses: misses / per_thread,
            dram_bytes: bytes / per_thread,
        };
        total_time += host::core::thread_time_s(cfg, &thread_act, 1.0 / cfg.exec_threads as f64);
        act.instructions += (instr as f64 * scale) as u64;
        act.l1_hits += (s.l1_hits as f64 * scale) as u64;
        act.l2_hits += (s.l2_hits as f64 * scale) as u64;
        act.llc_misses += misses;
        act.dram_bytes += bytes;
        dram.record_read(bytes);
    }

    let exec_time_s = total_time;
    let metrics = QueryMetrics {
        exec_time_s,
        pim_time_s: 0.0,
        read_time_s: 0.0,
        other_time_s: 0.0,
        llc_misses: act.llc_misses,
        host_energy_pj: host::power::host_energy_pj(
            cfg,
            exec_time_s,
            exec_time_s,
            cfg.exec_threads,
        ),
        dram_energy_pj: dram.total_energy_pj(exec_time_s),
        pim_energy: Default::default(),
        cycles: Default::default(),
        inter_cells: 0,
        opt: Default::default(),
        plan_cache: Default::default(),
        shards_skipped: 0,
        steps_short_circuited: 0,
        peak_chip_w: 0.0,
        avg_chip_w: 0.0,
        theoretical_chip_w: 0.0,
        ops_per_cell: 0.0,
        required_endurance_10yr: 0.0,
        endurance_breakdown: [0.0; 5],
    };

    RunReport {
        query: q.name,
        metrics,
        output,
    }
}

/// Scalar oracle for one relation's filter (differential tests). Dead
/// rows are excluded, mirroring the engines' valid-bit masking.
pub fn oracle_selected(db: &Database, rq: &RelQuery) -> u64 {
    let rel = db.rel(rq.rel);
    (0..rel.records)
        .filter(|&i| rel.live(i) && rq.filter.eval(&|n| rel.col(n)[i]))
        .count() as u64
}

/// Apply one DML statement to the host column store — the mutation twin
/// of the PIM path, so differential tests can hold a baseline mirror
/// bit-identical in its *live-record multiset* to the PIM copy.
///
/// Host cost accounting follows the §3.1 programming model: the scan
/// reads stream through the cache model, and every mutated cache line is
/// written *and flushed* (PIM data must not stay cached), so each dirty
/// line reaches memory — counted as an LLC miss and a DRAM transfer.
///
/// Semantics match [`crate::exec::pimdb::PimSession::run_dml`]: filters
/// see live rows only; DELETE clears liveness and zeroes the row (the
/// all-zero-dead-row invariant, so the mutated store reloads into PIM
/// correctly); INSERT appends one live record with unlisted attributes
/// encoded as 0.
///
/// Panics on a statement naming an unknown or repeated attribute — the
/// conditions `compile_dml` rejects with typed errors on the PIM side.
/// Validate there (or through the PQL lowering) first; a silently
/// half-applied statement would diverge the mirror from the PIM copy.
pub fn apply_dml(cfg: &SystemConfig, db: &mut Database, dml: &Dml) -> DmlResult {
    let rel_idx = schema::PIM_RELATIONS
        .iter()
        .position(|&r| r == dml.rel())
        .expect("DML targets a PIM relation");
    let written: &[(&'static str, u64)] = match dml {
        Dml::Insert { values, .. } => values,
        Dml::Update { sets, .. } => sets,
        Dml::Delete { .. } => &[],
    };
    for (i, (name, _)) in written.iter().enumerate() {
        assert!(
            schema::attr(dml.rel(), name).is_some(),
            "{:?} has no attribute {name}",
            dml.rel()
        );
        assert!(
            !written[..i].iter().any(|(n, _)| n == name),
            "{:?} attribute {name} listed twice",
            dml.rel()
        );
    }
    let rel = db.rel_mut(dml.rel());
    let mut cache = CacheSim::with_l2_share(cfg, cfg.exec_threads);
    let mut instr = 0u64;
    let mut flushed_lines = 0u64;
    let mut rows_affected = 0u64;

    let col_index: std::collections::BTreeMap<&str, usize> = rel
        .column_names()
        .iter()
        .enumerate()
        .map(|(i, n)| (*n, i))
        .collect();
    let touch = |cache: &mut CacheSim, flushed: &mut u64, name: &str, rec: usize, write: bool| {
        let w = attr_bytes(dml.rel(), name);
        let addr = col_base(rel_idx, col_index[name]) + rec as u64 * w;
        cache.access_range(addr, w as usize, write);
        if write {
            // §3.1: clflush the written lines so the store reaches the
            // media — the lines leave both cache levels and each counts
            // a memory transfer
            *flushed += cache.flush_range(addr, w as usize);
        }
    };

    match dml {
        Dml::Insert { values, .. } => {
            let owned: Vec<(&str, u64)> = values.iter().map(|(n, v)| (*n, *v)).collect();
            let row = rel.append_row(&owned);
            rows_affected = 1;
            for name in rel.column_names() {
                touch(&mut cache, &mut flushed_lines, name, row, true);
                instr += 2;
            }
        }
        Dml::Update { filter, sets, .. } => {
            let filter_attrs = filter.attrs();
            for rec in 0..rel.records {
                instr += 1;
                if !rel.live(rec) {
                    continue;
                }
                for a in &filter_attrs {
                    touch(&mut cache, &mut flushed_lines, a, rec, false);
                    instr += 2;
                }
                let hit = filter.eval(&|n| rel.col(n)[rec]);
                instr += 2;
                if !hit {
                    continue;
                }
                rows_affected += 1;
                for &(name, value) in sets.iter() {
                    rel.write(name, rec, value);
                    touch(&mut cache, &mut flushed_lines, name, rec, true);
                    instr += 2;
                }
            }
        }
        Dml::Delete { filter, .. } => {
            let filter_attrs = filter.attrs();
            for rec in 0..rel.records {
                instr += 1;
                if !rel.live(rec) {
                    continue;
                }
                for a in &filter_attrs {
                    touch(&mut cache, &mut flushed_lines, a, rec, false);
                    instr += 2;
                }
                let hit = filter.eval(&|n| rel.col(n)[rec]);
                instr += 2;
                if !hit {
                    continue;
                }
                rows_affected += 1;
                rel.set_valid(rec, false);
                rel.zero_row(rec);
                for name in rel.column_names() {
                    touch(&mut cache, &mut flushed_lines, name, rec, true);
                    instr += 2;
                }
            }
        }
    }

    // flushes force every dirty line to memory regardless of cache state
    let s = &cache.stats;
    let llc_misses = s.llc_misses + flushed_lines;
    let dram_bytes = llc_misses * cfg.cache_block as u64;
    let act = host::core::Activity {
        instructions: instr,
        l1_hits: s.l1_hits,
        l2_hits: s.l2_hits,
        llc_misses,
        dram_bytes,
    };
    let mut dram = DramModel::new(cfg);
    dram.record_read(dram_bytes);
    let exec_time_s =
        host::core::spawn_join_overhead_s(cfg, 1) + host::core::thread_time_s(cfg, &act, 1.0);
    let metrics = QueryMetrics {
        exec_time_s,
        llc_misses,
        host_energy_pj: host::power::host_energy_pj(cfg, exec_time_s, exec_time_s, 1),
        dram_energy_pj: dram.total_energy_pj(exec_time_s),
        ..Default::default()
    };
    DmlResult {
        rows_affected,
        wear_delta: 0.0, // DRAM endures; wear is a PIM-side concern
        metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::tpch;

    fn db() -> Database {
        Database::generate(0.001, 11)
    }

    #[test]
    fn baseline_matches_oracle_counts() {
        let cfg = SystemConfig::default();
        let database = db();
        for name in ["Q6", "Q12", "Q11", "Q19"] {
            let q = tpch::query(name).unwrap();
            let r = run_query(&cfg, &database, &q);
            for (rq, (rel_name, got)) in q.rels.iter().zip(&r.output.selected) {
                assert_eq!(rq.rel.name(), *rel_name);
                assert_eq!(*got, oracle_selected(&database, rq), "{name}");
            }
        }
    }

    #[test]
    fn baseline_time_scales_with_relation_size() {
        let cfg = SystemConfig::default();
        let database = db();
        let big = run_query(&cfg, &database, &tpch::query("Q14").unwrap()); // LINEITEM
        let small = run_query(&cfg, &database, &tpch::query("Q11").unwrap()); // SUPPLIER
        assert!(big.metrics.exec_time_s > small.metrics.exec_time_s * 10.0);
    }

    #[test]
    fn early_exit_reduces_accesses_vs_full_scan() {
        // Q17 filters brand (selective) then container; misses should be
        // well below touching every attribute of every record
        let cfg = SystemConfig::default();
        let database = db();
        let r = run_query(&cfg, &database, &tpch::query("Q17").unwrap());
        let part_records = crate::db::schema::RelId::Part.records_at_sf(cfg.report_sf);
        // upper bound: 2 attrs x 1 byte each / 64B line, plus slack
        assert!(r.metrics.llc_misses < part_records / 8);
    }

    #[test]
    fn apply_dml_mutates_and_scans_skip_dead_rows() {
        use crate::db::schema::RelId;
        use crate::query::ast::{CmpOp, Dml};
        let cfg = SystemConfig::default();
        let mut database = db();
        let before = database.rel(RelId::Supplier).live_count();

        let del = Dml::Delete {
            rel: RelId::Supplier,
            filter: Pred::CmpImm {
                attr: "s_suppkey",
                op: CmpOp::Le,
                value: 5,
            },
        };
        let r = apply_dml(&cfg, &mut database, &del);
        assert_eq!(r.rows_affected, 5);
        // flush accounting: mutations reach memory
        assert!(r.metrics.llc_misses > 0);
        assert!(r.metrics.exec_time_s > 0.0);
        assert_eq!(database.rel(RelId::Supplier).live_count(), before - 5);
        // deleted rows are zeroed (the all-zero-dead-row invariant)
        assert_eq!(database.rel(RelId::Supplier).col("s_suppkey")[0], 0);

        // deleting again affects nothing: dead rows are invisible
        let r = apply_dml(&cfg, &mut database, &del);
        assert_eq!(r.rows_affected, 0);

        let upd = Dml::Update {
            rel: RelId::Supplier,
            filter: Pred::CmpImm {
                attr: "s_suppkey",
                op: CmpOp::Eq,
                value: 6,
            },
            sets: vec![("s_nationkey", 24)],
        };
        assert_eq!(apply_dml(&cfg, &mut database, &upd).rows_affected, 1);
        assert_eq!(database.rel(RelId::Supplier).col("s_nationkey")[5], 24);

        let ins = Dml::Insert {
            rel: RelId::Supplier,
            values: vec![("s_suppkey", 12345)],
        };
        assert_eq!(apply_dml(&cfg, &mut database, &ins).rows_affected, 1);
        assert_eq!(
            database.rel(RelId::Supplier).live_count(),
            before - 5 + 1
        );

        // the baseline scan and the oracle both skip dead rows
        let rq = crate::query::lang::parse_rel_query(
            "from supplier | filter s_suppkey <= 6",
        )
        .unwrap();
        assert_eq!(oracle_selected(&database, &rq), 1); // only suppkey 6 lives
        let q = Query {
            name: "t",
            kind: QueryKind::FilterOnly,
            rels: vec![rq],
        };
        let rep = run_query(&cfg, &database, &q);
        assert_eq!(rep.output.selected[0].1, 1);
    }

    #[test]
    fn full_query_baseline_has_groups() {
        let cfg = SystemConfig::default();
        let database = db();
        let r = run_query(&cfg, &database, &tpch::query("Q1").unwrap());
        assert!(!r.output.groups.is_empty());
        assert!(r.output.groups.iter().all(|g| g.count > 0));
    }
}
