//! Always-on shard executor for the concurrent serving path.
//!
//! The batched wave scheduler in [`crate::exec::plan`] spins up scoped
//! worker threads per call — fine for one session running waves, wrong
//! for a serving runtime where many reader threads submit programs
//! continuously. [`ShardPool`] keeps a fixed set of workers alive for
//! the lifetime of the [`crate::api::Pimdb`] handle:
//!
//! * **per-worker queues + stealing** — each worker owns a deque;
//!   submissions round-robin across them and idle workers steal from
//!   their peers, so one slow shard never serializes the pool;
//! * **admission control** — at most `cap` shard jobs may be queued or
//!   running; further submissions block the *submitting* reader thread
//!   (back-pressure) instead of growing the queues without bound;
//! * **panic isolation** — a panicking shard job is caught at the pool
//!   boundary and surfaces as an [`ExecError`] on the submitting call,
//!   never as a dead worker.
//!
//! Shard jobs run [`engine::exec_steps_snapshot`] over `Arc`-shared
//! immutable crossbar snapshots, so any number of concurrent
//! [`ShardPool::run_snapshot`] calls — from any number of reader
//! threads — execute against the same relation version without
//! synchronizing with each other or with DML batch execution.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;

use crate::exec::engine::{self, ExecOutputs, XbarState};
use crate::exec::pimdb::EngineKind;
use crate::exec::plan::ExecPlan;
use crate::exec::ExecError;
use crate::query::compiler::Step;
use crate::query::opt::prune::ShortCircuit;
use crate::util::bits::WORDS;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Lock a pool-internal mutex, recovering from poison: pool bookkeeping
/// (queues, counters) stays consistent across a panicking job because
/// jobs run outside these critical sections.
fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

struct PoolShared {
    /// One job deque per worker (round-robin submit, peer stealing).
    queues: Vec<Mutex<VecDeque<Job>>>,
    /// Sleep lock + condvar for idle workers. Submitters notify while
    /// holding the lock, and workers re-check the queues under it before
    /// waiting, so a wakeup can never be lost.
    sleep: Mutex<()>,
    wake: Condvar,
    /// Jobs queued or running; `submit` blocks at `cap`.
    pending: Mutex<usize>,
    space: Condvar,
    cap: usize,
    shutdown: AtomicBool,
}

impl PoolShared {
    fn try_pop(&self, own: usize) -> Option<Job> {
        // own queue first, then steal round-robin from the peers
        let n = self.queues.len();
        for k in 0..n {
            let q = &self.queues[(own + k) % n];
            if let Some(job) = lock_recover(q).pop_front() {
                return Some(job);
            }
        }
        None
    }

    fn has_work(&self) -> bool {
        self.queues.iter().any(|q| !lock_recover(q).is_empty())
    }
}

/// Decrements the pending-jobs counter when the job finishes — by any
/// exit path, including a panic — and frees one admission slot.
struct PendingGuard(Arc<PoolShared>);

impl Drop for PendingGuard {
    fn drop(&mut self) {
        let mut p = lock_recover(&self.0.pending);
        *p = p.saturating_sub(1);
        drop(p);
        self.0.space.notify_one();
    }
}

/// The always-on executor. One per [`crate::api::Pimdb`]; dropped with
/// the handle (workers are signalled and joined).
pub(crate) struct ShardPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
    /// Round-robin submission cursor.
    next: AtomicUsize,
}

impl ShardPool {
    /// A pool with `parallelism` workers. `parallelism <= 1` spawns no
    /// threads: jobs run inline on the submitting thread (the serial
    /// reference path, bit-identical by construction). `admission` caps
    /// queued+running jobs; 0 picks `4 * parallelism`.
    pub(crate) fn new(parallelism: usize, admission: usize) -> ShardPool {
        let n_workers = if parallelism <= 1 { 0 } else { parallelism };
        let cap = if admission == 0 {
            4 * parallelism.max(1)
        } else {
            admission
        }
        .max(1);
        let shared = Arc::new(PoolShared {
            queues: (0..n_workers.max(1)).map(|_| Mutex::new(VecDeque::new())).collect(),
            sleep: Mutex::new(()),
            wake: Condvar::new(),
            pending: Mutex::new(0),
            space: Condvar::new(),
            cap,
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..n_workers)
            .map(|idx| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(sh, idx))
            })
            .collect();
        ShardPool {
            shared,
            workers,
            next: AtomicUsize::new(0),
        }
    }

    /// Submit one job. Blocks while the pool is at its admission cap;
    /// runs the job inline in serial mode.
    fn submit(&self, job: Job) {
        if self.workers.is_empty() {
            job();
            return;
        }
        let sh = &self.shared;
        {
            let mut p = lock_recover(&sh.pending);
            while *p >= sh.cap {
                p = sh
                    .space
                    .wait(p)
                    .unwrap_or_else(PoisonError::into_inner);
            }
            *p += 1;
        }
        let guard_sh = Arc::clone(sh);
        let wrapped: Job = Box::new(move || {
            let _slot = PendingGuard(guard_sh);
            // the job's own result channel reports panics; this catch
            // keeps the worker thread alive
            let _ = catch_unwind(AssertUnwindSafe(job));
        });
        let i = self.next.fetch_add(1, Ordering::Relaxed) % sh.queues.len();
        lock_recover(&sh.queues[i]).push_back(wrapped);
        // notify under the sleep lock: pairs with the worker's re-check
        let _g = lock_recover(&sh.sleep);
        sh.wake.notify_one();
    }

    /// Execute a compiled program over an `Arc`-shared crossbar snapshot,
    /// sharded per `plan`, without mutating the snapshot. `seed_masks`
    /// (one plane per crossbar) replays a cached shared-scan mask, in
    /// which case `steps` is the program's suffix. `skip` (one flag per
    /// crossbar) is a zone-map skip bitmap and `sc` the program's
    /// short-circuit schedule — both are sliced per shard and forwarded
    /// to the engine (native path only; the PJRT backend runs the full
    /// program, with identical outputs and zero skip counters). Returns
    /// the merged outputs in crossbar order plus every crossbar's final
    /// mask plane.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_snapshot(
        &self,
        states: &Arc<Vec<XbarState>>,
        compute_base: usize,
        steps: &[Step],
        mask_col: usize,
        seed_masks: Option<&Arc<Vec<[u64; WORDS]>>>,
        skip: Option<&Arc<Vec<bool>>>,
        sc: Option<&ShortCircuit>,
        engine_kind: EngineKind,
        plan: &ExecPlan,
    ) -> Result<(ExecOutputs, Vec<[u64; WORDS]>), ExecError> {
        if states.is_empty() {
            // keep the output shape identical to the serial interpreter
            return Ok(engine::exec_steps_snapshot(
                &[],
                compute_base,
                steps,
                mask_col,
                None,
                None,
                None,
            ));
        }
        debug_assert!(seed_masks.is_none_or(|s| s.len() == states.len()));
        debug_assert!(skip.is_none_or(|s| s.len() == states.len()));
        let shard_len = plan.shard_len(states.len());
        let ranges: Vec<std::ops::Range<usize>> = (0..states.len())
            .step_by(shard_len)
            .map(|lo| lo..(lo + shard_len).min(states.len()))
            .collect();
        let (tx, rx) = mpsc::channel();
        let steps_arc: Arc<Vec<Step>> = Arc::new(steps.to_vec());
        let sc_arc: Option<Arc<ShortCircuit>> = sc.map(|s| Arc::new(s.clone()));
        for (i, r) in ranges.iter().enumerate() {
            let states = Arc::clone(states);
            let steps = Arc::clone(&steps_arc);
            let seeds = seed_masks.map(Arc::clone);
            let skip = skip.map(Arc::clone);
            let sc = sc_arc.clone();
            let tx = tx.clone();
            let r = r.clone();
            self.submit(Box::new(move || {
                let result = catch_unwind(AssertUnwindSafe(|| {
                    run_shard(
                        &states[r.clone()],
                        compute_base,
                        &steps,
                        mask_col,
                        seeds.as_ref().map(|s| &s[r.clone()]),
                        skip.as_ref().map(|s| &s[r.clone()]),
                        sc.as_deref(),
                        engine_kind,
                    )
                }))
                .unwrap_or_else(|_| {
                    Err(ExecError::Backend {
                        engine: "native",
                        msg: "shard job panicked".into(),
                    })
                });
                let _ = tx.send((i, result));
            }));
        }
        drop(tx);
        let mut partials: Vec<(usize, (ExecOutputs, Vec<[u64; WORDS]>))> =
            Vec::with_capacity(ranges.len());
        for _ in 0..ranges.len() {
            let (i, result) = rx.recv().map_err(|_| ExecError::Backend {
                engine: "native",
                msg: "shard executor shut down mid-program".into(),
            })?;
            partials.push((i, result?));
        }
        partials.sort_by_key(|&(i, _)| i);
        let mut merged: Option<(ExecOutputs, Vec<[u64; WORDS]>)> = None;
        for (_, (out, masks)) in partials {
            match merged.as_mut() {
                None => merged = Some((out, masks)),
                Some((m_out, m_masks)) => {
                    debug_assert_eq!(m_out.reduces.len(), out.reduces.len());
                    for (dst, src) in m_out.reduces.iter_mut().zip(out.reduces) {
                        dst.extend(src);
                    }
                    m_out.mask_counts.extend(out.mask_counts);
                    m_out.shards_skipped += out.shards_skipped;
                    m_out.steps_short_circuited += out.steps_short_circuited;
                    m_masks.extend(masks);
                }
            }
        }
        Ok(merged.expect("at least one shard"))
    }

    /// Execute a fused multi-query scan program (one
    /// [`crate::query::opt::fusion::FusedScan`]) over an `Arc`-shared
    /// crossbar snapshot, sharded per `plan`, and capture one mask plane
    /// per member query per crossbar. Element `[q][x]` of the result is
    /// member `q`'s filter mask on crossbar `x`, in crossbar order —
    /// exactly what [`Self::run_snapshot`] would have captured running
    /// member `q`'s own prefix.
    pub(crate) fn run_fused(
        &self,
        states: &Arc<Vec<XbarState>>,
        compute_base: usize,
        steps: &[Step],
        mask_cols: &[usize],
        engine_kind: EngineKind,
        plan: &ExecPlan,
    ) -> Result<Vec<Vec<[u64; WORDS]>>, ExecError> {
        if states.is_empty() {
            return Ok(vec![Vec::new(); mask_cols.len()]);
        }
        let shard_len = plan.shard_len(states.len());
        let ranges: Vec<std::ops::Range<usize>> = (0..states.len())
            .step_by(shard_len)
            .map(|lo| lo..(lo + shard_len).min(states.len()))
            .collect();
        let (tx, rx) = mpsc::channel();
        let steps_arc: Arc<Vec<Step>> = Arc::new(steps.to_vec());
        let cols_arc: Arc<Vec<usize>> = Arc::new(mask_cols.to_vec());
        for (i, r) in ranges.iter().enumerate() {
            let states = Arc::clone(states);
            let steps = Arc::clone(&steps_arc);
            let cols = Arc::clone(&cols_arc);
            let tx = tx.clone();
            let r = r.clone();
            self.submit(Box::new(move || {
                let result = catch_unwind(AssertUnwindSafe(|| {
                    run_fused_shard(&states[r.clone()], compute_base, &steps, &cols, engine_kind)
                }))
                .unwrap_or_else(|_| {
                    Err(ExecError::Backend {
                        engine: "native",
                        msg: "fused shard job panicked".into(),
                    })
                });
                let _ = tx.send((i, result));
            }));
        }
        drop(tx);
        let mut partials: Vec<(usize, Vec<Vec<[u64; WORDS]>>)> = Vec::with_capacity(ranges.len());
        for _ in 0..ranges.len() {
            let (i, result) = rx.recv().map_err(|_| ExecError::Backend {
                engine: "native",
                msg: "shard executor shut down mid-program".into(),
            })?;
            partials.push((i, result?));
        }
        partials.sort_by_key(|&(i, _)| i);
        let mut merged = vec![Vec::with_capacity(states.len()); mask_cols.len()];
        for (_, shard_planes) in partials {
            for (dst, src) in merged.iter_mut().zip(shard_planes) {
                dst.extend(src);
            }
        }
        Ok(merged)
    }
}

/// One shard's work: snapshot-interpret natively, or clone-and-run for
/// the PJRT backend (its kernels mutate state in place, so the snapshot
/// guarantee is met by handing it a private copy of the shard). The skip
/// bitmap and short-circuit schedule apply to the native interpreter
/// only: the PJRT kernels run the full program — bit-identical outputs
/// by the zone/short-circuit proofs, just without the shortcut — so its
/// skip counters stay zero.
#[allow(clippy::too_many_arguments)]
fn run_shard(
    shard: &[XbarState],
    compute_base: usize,
    steps: &[Step],
    mask_col: usize,
    seed_masks: Option<&[[u64; WORDS]]>,
    skip: Option<&[bool]>,
    sc: Option<&ShortCircuit>,
    engine_kind: EngineKind,
) -> Result<(ExecOutputs, Vec<[u64; WORDS]>), ExecError> {
    match engine_kind {
        EngineKind::Native => Ok(engine::exec_steps_snapshot(
            shard,
            compute_base,
            steps,
            mask_col,
            seed_masks,
            skip,
            sc,
        )),
        EngineKind::Pjrt => {
            let mut owned: Vec<XbarState> = shard.to_vec();
            if let Some(seeds) = seed_masks {
                for (st, m) in owned.iter_mut().zip(seeds) {
                    st.planes[mask_col] = *m;
                }
            }
            let out = crate::runtime::exec_steps_pjrt(&mut owned, steps, mask_col).map_err(
                |msg| ExecError::Backend {
                    engine: "pjrt",
                    msg,
                },
            )?;
            let masks = owned.iter().map(|st| st.planes[mask_col]).collect();
            Ok((out, masks))
        }
    }
}

/// One fused-scan shard's work: multi-mask snapshot interpretation
/// natively, or clone-and-run for the PJRT backend with every requested
/// mask plane read back from the private copy.
fn run_fused_shard(
    shard: &[XbarState],
    compute_base: usize,
    steps: &[Step],
    mask_cols: &[usize],
    engine_kind: EngineKind,
) -> Result<Vec<Vec<[u64; WORDS]>>, ExecError> {
    match engine_kind {
        EngineKind::Native => Ok(engine::exec_steps_fused(
            shard,
            compute_base,
            steps,
            mask_cols,
        )),
        EngineKind::Pjrt => {
            let mut owned: Vec<XbarState> = shard.to_vec();
            let probe = mask_cols.first().copied().unwrap_or(compute_base);
            crate::runtime::exec_steps_pjrt(&mut owned, steps, probe).map_err(|msg| {
                ExecError::Backend {
                    engine: "pjrt",
                    msg,
                }
            })?;
            Ok(mask_cols
                .iter()
                .map(|&mc| owned.iter().map(|st| st.planes[mc]).collect())
                .collect())
        }
    }
}

fn worker_loop(sh: Arc<PoolShared>, idx: usize) {
    loop {
        if let Some(job) = sh.try_pop(idx) {
            job();
            continue;
        }
        let g = lock_recover(&sh.sleep);
        if sh.shutdown.load(Ordering::Acquire) {
            break;
        }
        // re-check under the sleep lock: a submitter that pushed after
        // our try_pop must take this lock to notify, so either we see
        // the job here or the notification reaches our wait below
        if sh.has_work() {
            continue;
        }
        let _g = sh.wake.wait(g).unwrap_or_else(PoisonError::into_inner);
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _g = lock_recover(&self.shared.sleep);
            self.shared.wake.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::endurance::OpCategory;
    use crate::pim::isa::{ColRange, Opcode, PimInstruction};
    use crate::util::rng::Rng;

    fn step(instr: PimInstruction) -> Step {
        Step {
            instr,
            category: OpCategory::Filter,
        }
    }

    fn random_states(seed: u64, n: usize) -> Vec<XbarState> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut st = XbarState::new(160);
                for c in 0..32 {
                    for w in 0..WORDS {
                        st.planes[c][w] = rng.next_u64();
                    }
                }
                st
            })
            .collect()
    }

    fn program() -> Vec<Step> {
        vec![
            step(PimInstruction::with_imm(
                Opcode::LtImm,
                ColRange::new(0, 16),
                ColRange::new(100, 1),
                0x1234,
            )),
            step(PimInstruction::binary(
                Opcode::And,
                ColRange::new(0, 16),
                ColRange::new(100, 1),
                ColRange::new(110, 16),
            )),
            step(PimInstruction::unary(
                Opcode::ReduceSum,
                ColRange::new(110, 16),
                ColRange::new(110, 16),
            )),
        ]
    }

    #[test]
    fn pool_matches_serial_wave_executor() {
        let steps = program();
        for &(workers, n_xbars) in &[(1usize, 5usize), (2, 7), (8, 11), (4, 1)] {
            let pool = ShardPool::new(workers, 0);
            let plan = ExecPlan::with_parallelism(workers);
            let mut serial = random_states(90 + n_xbars as u64, n_xbars);
            let want = engine::exec_steps_native(&mut serial, &steps, 100);
            let shared = Arc::new(random_states(90 + n_xbars as u64, n_xbars));
            let (got, masks) = pool
                .run_snapshot(
                    &shared,
                    64,
                    &steps,
                    100,
                    None,
                    None,
                    None,
                    EngineKind::Native,
                    &plan,
                )
                .unwrap();
            assert_eq!(got.reduces, want.reduces, "{workers} workers");
            assert_eq!(got.mask_counts, want.mask_counts);
            for (x, m) in masks.iter().enumerate() {
                assert_eq!(*m, serial[x].planes[100]);
            }
        }
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        let steps = Arc::new(program());
        let pool = Arc::new(ShardPool::new(4, 2)); // tight admission cap
        let plan = ExecPlan::with_parallelism(4);
        let shared = Arc::new(random_states(7, 9));
        let mut serial = random_states(7, 9);
        let want = engine::exec_steps_native(&mut serial, &steps, 100);
        std::thread::scope(|s| {
            for _ in 0..8 {
                let pool = Arc::clone(&pool);
                let shared = Arc::clone(&shared);
                let steps = Arc::clone(&steps);
                let want = want.clone();
                s.spawn(move || {
                    for _ in 0..10 {
                        let (got, _) = pool
                            .run_snapshot(
                                &shared,
                                64,
                                &steps,
                                100,
                                None,
                                None,
                                None,
                                EngineKind::Native,
                                &plan,
                            )
                            .unwrap();
                        assert_eq!(got.reduces, want.reduces);
                        assert_eq!(got.mask_counts, want.mask_counts);
                    }
                });
            }
        });
        // the snapshot was never mutated by 80 concurrent executions
        let pristine = random_states(7, 9);
        for (a, b) in shared.iter().zip(&pristine) {
            assert_eq!(a.planes, b.planes);
        }
    }

    #[test]
    fn replay_seed_runs_suffix_only() {
        let steps = program();
        let pool = ShardPool::new(2, 0);
        let plan = ExecPlan::with_parallelism(2);
        let shared = Arc::new(random_states(21, 6));
        let (want, masks) = pool
            .run_snapshot(
                &shared,
                64,
                &steps,
                100,
                None,
                None,
                None,
                EngineKind::Native,
                &plan,
            )
            .unwrap();
        let seeds = Arc::new(masks);
        let (got, masks2) = pool
            .run_snapshot(
                &shared,
                64,
                &steps[1..],
                100,
                Some(&seeds),
                None,
                None,
                EngineKind::Native,
                &plan,
            )
            .unwrap();
        assert_eq!(got.reduces, want.reduces);
        assert_eq!(got.mask_counts, want.mask_counts);
        assert_eq!(&masks2, seeds.as_ref());
    }

    #[test]
    fn skip_bitmap_and_short_circuit_are_pure_shortcuts() {
        // mask program whose mask is provably zero on an all-zero
        // crossbar: GtImm(c0 > 5) -> c100; And(c100, c1) -> c100
        // (combine); masked And + reduce as the suffix
        let steps = vec![
            step(PimInstruction::with_imm(
                Opcode::GtImm,
                ColRange::new(0, 16),
                ColRange::new(100, 1),
                5,
            )),
            step(PimInstruction::binary(
                Opcode::And,
                ColRange::new(100, 1),
                ColRange::new(1, 1),
                ColRange::new(100, 1),
            )),
            step(PimInstruction::binary(
                Opcode::And,
                ColRange::new(0, 16),
                ColRange::new(100, 1),
                ColRange::new(110, 16),
            )),
            step(PimInstruction::unary(
                Opcode::ReduceSum,
                ColRange::new(110, 16),
                ColRange::new(110, 16),
            )),
        ];
        // crossbars 1 and 3 are all-zero and zone-skipped; crossbar 4 is
        // all-zero but *not* skipped, so the runtime short-circuit fires
        let mut states = random_states(55, 5);
        states[1] = XbarState::new(160);
        states[3] = XbarState::new(160);
        states[4] = XbarState::new(160);
        let mut serial = states.clone();
        let want = engine::exec_steps_native(&mut serial, &steps, 100);
        let shared = Arc::new(states);
        let skip = Arc::new(vec![false, true, false, true, false]);
        let sc = crate::query::opt::prune::short_circuit(&steps, 100, 2).unwrap();
        assert_eq!(sc.checks, vec![0]);
        assert_eq!(sc.resume, 2);
        for workers in [1usize, 2, 8] {
            let pool = ShardPool::new(workers, 0);
            let plan = ExecPlan::with_parallelism(workers);
            let (got, masks) = pool
                .run_snapshot(
                    &shared,
                    64,
                    &steps,
                    100,
                    None,
                    Some(&skip),
                    Some(&sc),
                    EngineKind::Native,
                    &plan,
                )
                .unwrap();
            assert_eq!(got.reduces, want.reduces, "{workers} workers");
            assert_eq!(got.mask_counts, want.mask_counts, "{workers} workers");
            assert_eq!(got.shards_skipped, 2, "{workers} workers");
            assert_eq!(got.steps_short_circuited, 1, "{workers} workers");
            for (x, m) in masks.iter().enumerate() {
                assert_eq!(*m, serial[x].planes[100], "crossbar {x}");
            }
        }
    }

    #[test]
    fn fused_run_matches_per_query_snapshot_runs() {
        // a hand-fused two-member program: each member's mask is one of
        // the two compare steps' outputs
        let fused = vec![
            step(PimInstruction::with_imm(
                Opcode::LtImm,
                ColRange::new(0, 16),
                ColRange::new(100, 1),
                0x1234,
            )),
            step(PimInstruction::with_imm(
                Opcode::GtImm,
                ColRange::new(0, 16),
                ColRange::new(101, 1),
                0x4321,
            )),
        ];
        for &(workers, n_xbars) in &[(1usize, 5usize), (2, 7), (8, 11)] {
            let pool = ShardPool::new(workers, 0);
            let plan = ExecPlan::with_parallelism(workers);
            let shared = Arc::new(random_states(130 + n_xbars as u64, n_xbars));
            let got = pool
                .run_fused(&shared, 64, &fused, &[100, 101], EngineKind::Native, &plan)
                .unwrap();
            let (_, want0) = pool
                .run_snapshot(
                    &shared,
                    64,
                    &fused[..1],
                    100,
                    None,
                    None,
                    None,
                    EngineKind::Native,
                    &plan,
                )
                .unwrap();
            let (_, want1) = pool
                .run_snapshot(
                    &shared,
                    64,
                    &fused[1..],
                    101,
                    None,
                    None,
                    None,
                    EngineKind::Native,
                    &plan,
                )
                .unwrap();
            assert_eq!(got[0], want0, "{workers} workers");
            assert_eq!(got[1], want1, "{workers} workers");
        }
    }

    #[test]
    fn admission_cap_defaults_and_overrides() {
        assert_eq!(ShardPool::new(4, 0).shared.cap, 16);
        assert_eq!(ShardPool::new(4, 3).shared.cap, 3);
        assert_eq!(ShardPool::new(1, 0).workers.len(), 0);
        assert_eq!(ShardPool::new(8, 0).workers.len(), 8);
    }

    #[test]
    fn pjrt_jobs_error_cleanly_when_runtime_missing() {
        if crate::runtime::runtime_available() {
            return; // real runtime present: covered by differential tests
        }
        let pool = ShardPool::new(2, 0);
        let plan = ExecPlan::with_parallelism(2);
        let shared = Arc::new(random_states(3, 2));
        let err = pool
            .run_snapshot(
                &shared,
                64,
                &program(),
                100,
                None,
                None,
                None,
                EngineKind::Pjrt,
                &plan,
            )
            .unwrap_err();
        let ExecError::Backend { engine, msg } = err;
        assert_eq!(engine, "pjrt");
        assert!(!msg.is_empty());
    }
}
