//! Functional execution engine: interprets PIM instructions over
//! bit-plane crossbar states.
//!
//! A crossbar's functional state is one bit-plane per column, packed as
//! `u64[WORDS]` (16 words, one cache line per plane) so the fixed-width
//! word loops below autovectorize. The L1 Pallas kernels keep their own
//! `u32[KERNEL_WORDS]` packing (DESIGN.md §Hardware-Adaptation); the PJRT
//! path in [`crate::runtime`] converts at the literal boundary, so both
//! backends stay differential-testable on identical functional state.
//!
//! ISA semantics notes (paper §4.2, §5.2.2):
//!  * And/Or with a single-column second operand broadcast the mask bit
//!    across the first operand's width (the paper's reduce pre-masking).
//!  * Reduce instructions cover *all* crossbar rows; the compiler masks or
//!    adjusts non-selected rows beforehand.
//!  * ColumnTransform is a data-movement op; functionally the mask column
//!    is unchanged (the read path fetches it row-oriented).

use crate::db::dbgen::Relation;
use crate::db::layout::RelationLayout;
use crate::pim::isa::{ColRange, Opcode, PimInstruction};
use crate::query::compiler::Step;
use crate::query::opt::prune::ShortCircuit;
use crate::util::bits::{
    is_zero_words, load_lanes, popcount_words, store_lanes, vand, vnot, vor, vxor, PLANES, WORDS,
    WORD_BITS, WORD_CHUNKS, XBAR_ROWS,
};

/// Functional state of one crossbar: `planes[c]` holds column `c` of all
/// 1024 rows.
#[derive(Clone)]
pub struct XbarState {
    /// One packed bit-plane per crossbar column.
    pub planes: Vec<[u64; WORDS]>,
}

impl XbarState {
    /// An all-zero crossbar with `cols` columns.
    pub fn new(cols: usize) -> Self {
        XbarState {
            planes: vec![[0u64; WORDS]; cols],
        }
    }

    #[inline]
    fn set_bit(&mut self, col: usize, row: usize, v: bool) {
        debug_assert!(
            col < self.planes.len() && row < XBAR_ROWS,
            "set_bit out of range: col {col}/{}, row {row}/{XBAR_ROWS}",
            self.planes.len()
        );
        let w = &mut self.planes[col][row / WORD_BITS];
        let m = 1u64 << (row % WORD_BITS);
        if v {
            *w |= m;
        } else {
            *w &= !m;
        }
    }

    /// Write `value` into columns [start, start+len) of `row` — the
    /// functional effect of a host row write (INSERT, paper §3.1: PIM
    /// data is written with ordinary stores). Both 0- and 1-bits are
    /// written, so the call is correct for any prior row contents.
    pub fn write_value(&mut self, row: usize, r: ColRange, value: u64) {
        for i in 0..r.len as usize {
            self.set_bit(r.start as usize + i, row, (value >> i) & 1 == 1);
        }
    }

    /// Value of columns [start, start+len) in `row`.
    pub fn value_at(&self, row: usize, r: ColRange) -> u64 {
        debug_assert!(
            row < XBAR_ROWS && r.start as usize + r.len as usize <= self.planes.len(),
            "value_at out of range: row {row}/{XBAR_ROWS}, cols {}..{} of {}",
            r.start,
            r.start as usize + r.len as usize,
            self.planes.len()
        );
        let mut v = 0u64;
        for i in 0..r.len as usize {
            if (self.planes[r.start as usize + i][row / WORD_BITS] >> (row % WORD_BITS)) & 1 == 1 {
                v |= 1 << i;
            }
        }
        v
    }

    /// Number of set bits in column `col` across all rows.
    pub fn popcount_col(&self, col: usize) -> u64 {
        popcount_words(&self.planes[col])
    }
}

/// Column-plane storage the instruction interpreter runs against.
///
/// Two implementations: [`XbarState`] (the in-place path — DML and the
/// legacy wave executor mutate the crossbar arrays directly) and
/// [`SnapshotView`] (the concurrent read path — data columns come from a
/// shared immutable snapshot, compute columns from a private scratch, so
/// any number of readers interpret the same crossbars without
/// synchronization). Loads return the plane by value, matching the word
/// copies the kernels always made.
pub(crate) trait Planes {
    /// Load the packed plane of column `col`.
    fn ld(&self, col: usize) -> [u64; WORDS];
    /// Store the packed plane of column `col`.
    fn st(&mut self, col: usize, v: [u64; WORDS]);
}

impl Planes for XbarState {
    #[inline]
    fn ld(&self, col: usize) -> [u64; WORDS] {
        self.planes[col]
    }

    #[inline]
    fn st(&mut self, col: usize, v: [u64; WORDS]) {
        self.planes[col] = v;
    }
}

/// Read-only view of one crossbar for snapshot execution: data columns
/// (below `compute_base`) read through to the shared [`XbarState`];
/// compute columns live in a private zeroed scratch. Compiled programs
/// write *only* at/above `compute_base` (the compiler's column
/// discipline, re-checked here by a debug assert), which is exactly what
/// makes lock-free shared-snapshot execution sound — and the zeroed
/// scratch matches the `clear_compute` invariant the in-place path
/// maintains between programs.
pub(crate) struct SnapshotView<'a> {
    data: &'a XbarState,
    compute_base: usize,
    scratch: Vec<[u64; WORDS]>,
}

impl<'a> SnapshotView<'a> {
    /// A view over `data` whose compute area starts at `compute_base`.
    pub(crate) fn new(data: &'a XbarState, compute_base: usize) -> Self {
        SnapshotView {
            data,
            compute_base,
            scratch: vec![[0u64; WORDS]; data.planes.len().saturating_sub(compute_base)],
        }
    }
}

impl Planes for SnapshotView<'_> {
    #[inline]
    fn ld(&self, col: usize) -> [u64; WORDS] {
        if col < self.compute_base {
            self.data.planes[col]
        } else {
            self.scratch[col - self.compute_base]
        }
    }

    #[inline]
    fn st(&mut self, col: usize, v: [u64; WORDS]) {
        debug_assert!(
            col >= self.compute_base,
            "snapshot execution wrote data column {col} (compute base {})",
            self.compute_base
        );
        self.scratch[col - self.compute_base] = v;
    }
}

/// Load a relation partition into crossbar states (records -> rows,
/// attributes -> column slots, VALID bit set on occupied rows).
///
/// Word-at-a-time transpose: for each attribute, 64 consecutive records
/// are gathered into one u64 per bit-plane, writing each plane word
/// exactly once (this routine was 40% of the end-to-end profile when it
/// set bits one at a time — see EXPERIMENTS.md §Perf).
pub fn load_states(
    rel: &Relation,
    layout: &RelationLayout,
    cols: usize,
    rec_range: std::ops::Range<usize>,
) -> Vec<XbarState> {
    let n = rec_range.len();
    let n_xbars = n.div_ceil(XBAR_ROWS).max(1);
    let mut states = vec![XbarState::new(cols); n_xbars];
    for slot in &layout.slots {
        let col = &rel.col(slot.attr.name)[rec_range.clone()];
        for (w, chunk) in col.chunks(WORD_BITS).enumerate() {
            let (x, word) = (w / WORDS, w % WORDS);
            let planes = &mut states[x].planes;
            for b in 0..slot.attr.bits {
                let mut bits = 0u64;
                for (i, &v) in chunk.iter().enumerate() {
                    bits |= ((v >> b) & 1) << i;
                }
                planes[slot.start + b][word] = bits;
            }
        }
    }
    // VALID column from the store's liveness flags (all-true for a
    // pristine load; a DML-mutated store reloads with its dead rows
    // masked out — their data is zero by the all-zero-dead-row invariant)
    for i in (0..n).step_by(WORD_BITS) {
        let (x, word) = (i / XBAR_ROWS, (i % XBAR_ROWS) / WORD_BITS);
        let mut bits = 0u64;
        for b in 0..WORD_BITS.min(n - i) {
            if rel.live(rec_range.start + i + b) {
                bits |= 1 << b;
            }
        }
        states[x].planes[layout.valid_col][word] = bits;
    }
    states
}

/// Outputs of running a compiled program over a crossbar batch.
#[derive(Clone, Debug, Default)]
pub struct ExecOutputs {
    /// reduces[reduce_idx][xbar] — per-crossbar aggregate values, combined
    /// at the host (the paper's per-crossbar read + host combine).
    pub reduces: Vec<Vec<u128>>,
    /// Selected records per crossbar (popcount of the filter mask).
    pub mask_counts: Vec<u64>,
    /// Crossbars the executor never ran because a zone-map skip bitmap
    /// proved their mask all-zero (statistics-driven pruning).
    pub shards_skipped: u64,
    /// Filter-prefix steps abandoned by the runtime all-zero mask
    /// short-circuit, summed over crossbars.
    pub steps_short_circuited: u64,
}

impl ExecOutputs {
    /// Selected records summed over all crossbars.
    pub fn total_selected(&self) -> u64 {
        self.mask_counts.iter().sum()
    }

    /// Host-side combine of one reduce across crossbars.
    pub fn combined(&self, reduce_idx: usize) -> u128 {
        self.reduces[reduce_idx].iter().sum()
    }
}

/// Reusable kernel scratch, allocated once per shard and threaded through
/// [`exec_instr`] so the interpreter's only heap-sized temporary (the Mul
/// shift-add accumulator) is not re-established per instruction.
pub struct Scratch {
    /// Mul accumulator planes (`PLANES` wide, zeroed per Mul).
    mul_acc: Vec<[u64; WORDS]>,
}

impl Scratch {
    /// A scratch arena sized for the widest destination the ISA allows.
    pub fn new() -> Self {
        Scratch {
            mul_acc: vec![[0u64; WORDS]; PLANES],
        }
    }
}

impl Default for Scratch {
    fn default() -> Self {
        Scratch::new()
    }
}

/// Interpret one instruction on one crossbar state. Reduce ops append to
/// `reduce_out` instead of mutating columns. `scratch` is reused across
/// calls (see [`Scratch`]).
pub fn exec_instr(
    st: &mut XbarState,
    instr: &PimInstruction,
    reduce_out: &mut Vec<u128>,
    scratch: &mut Scratch,
) {
    exec_instr_on(st, instr, reduce_out, scratch)
}

/// The interpreter itself, generic over the plane store so the in-place
/// path ([`XbarState`]) and the lock-free snapshot path
/// ([`SnapshotView`]) run the identical kernels.
pub(crate) fn exec_instr_on<P: Planes>(
    st: &mut P,
    instr: &PimInstruction,
    reduce_out: &mut Vec<u128>,
    scratch: &mut Scratch,
) {
    let a = instr.src_a;
    let d = instr.dst;
    match instr.op {
        Opcode::EqImm | Opcode::NeImm | Opcode::LtImm | Opcode::GtImm => {
            let (eq, lt) = cmp_imm_planes(st, a, instr.imm);
            let out = match instr.op {
                Opcode::EqImm => eq,
                Opcode::NeImm => not_words(&eq),
                Opcode::LtImm => lt,
                Opcode::GtImm => not_words(&or_words(&lt, &eq)),
                _ => unreachable!(),
            };
            st.st(d.start as usize, out);
        }
        Opcode::Eq | Opcode::Lt => {
            let b = instr.src_b.expect("binary cmp");
            let (eq, lt) = cmp_cols_planes(st, a, b);
            st.st(d.start as usize, if instr.op == Opcode::Eq { eq } else { lt });
        }
        Opcode::AddImm => {
            // Same loop bound and zero-extension as Add: a widening
            // AddImm (dst wider than src) must propagate the final carry
            // into the top destination planes instead of leaving them
            // stale (they may hold garbage from a released scratch span).
            let n = d.len as usize;
            let mut carry = [0u64; WORDS];
            for i in 0..n {
                let pa = plane_or_zero(st, a, i);
                let bit = (instr.imm >> i) & 1;
                let pb = if bit == 1 { [u64::MAX; WORDS] } else { [0u64; WORDS] };
                let (s, c) = full_add(&pa, &pb, &carry);
                st.st(d.start as usize + i, s);
                carry = c;
            }
        }
        Opcode::Add => {
            let b = instr.src_b.expect("add");
            let n = d.len as usize;
            let mut carry = [0u64; WORDS];
            for i in 0..n {
                let pa = plane_or_zero(st, a, i);
                let pb = plane_or_zero(st, b, i);
                let (s, c) = full_add(&pa, &pb, &carry);
                st.st(d.start as usize + i, s);
                carry = c;
            }
        }
        Opcode::Mul => {
            let b = instr.src_b.expect("mul");
            let n = d.len as usize;
            // shard-arena accumulator (n <= PLANES planes): keeps the
            // shift-add inner loop allocation-free — Q1 runs thousands
            // of Muls
            debug_assert!(n <= PLANES);
            let acc = &mut scratch.mul_acc[..n];
            for p in acc.iter_mut() {
                *p = [0u64; WORDS];
            }
            for i in 0..b.len as usize {
                let m = st.ld(b.start as usize + i);
                let mut carry = [0u64; WORDS];
                for j in 0..(a.len as usize).min(n - i) {
                    let ad = and_words(&st.ld(a.start as usize + j), &m);
                    let (s, c) = full_add(&acc[i + j], &ad, &carry);
                    acc[i + j] = s;
                    carry = c;
                }
                let mut k = i + a.len as usize;
                while k < n && carry != [0u64; WORDS] {
                    let (s, c) = full_add(&acc[k], &[0u64; WORDS], &carry);
                    acc[k] = s;
                    carry = c;
                    k += 1;
                }
            }
            for j in 0..n {
                st.st(d.start as usize + j, scratch.mul_acc[j]);
            }
        }
        Opcode::Set => {
            for i in 0..d.len as usize {
                st.st(d.start as usize + i, [u64::MAX; WORDS]);
            }
        }
        Opcode::Reset => {
            for i in 0..d.len as usize {
                st.st(d.start as usize + i, [0u64; WORDS]);
            }
        }
        Opcode::Not => {
            for i in 0..a.len as usize {
                let v = not_words(&st.ld(a.start as usize + i));
                st.st(d.start as usize + i, v);
            }
        }
        Opcode::And | Opcode::Or => {
            let b = instr.src_b.expect("and/or");
            let broadcast = b.len == 1 && a.len > 1;
            for i in 0..a.len as usize {
                let pb = if broadcast {
                    st.ld(b.start as usize)
                } else {
                    plane_or_zero(st, b, i)
                };
                let pa = st.ld(a.start as usize + i);
                let v = if instr.op == Opcode::And {
                    and_words(&pa, &pb)
                } else {
                    or_words(&pa, &pb)
                };
                st.st(d.start as usize + i, v);
            }
        }
        Opcode::ReduceSum => {
            let mut sum: u128 = 0;
            for i in 0..a.len as usize {
                let pc = popcount_words(&st.ld(a.start as usize + i));
                sum += (pc as u128) << i;
            }
            reduce_out.push(sum);
        }
        Opcode::ReduceMin | Opcode::ReduceMax => {
            let is_min = instr.op == Opcode::ReduceMin;
            let mut cand = [u64::MAX; WORDS];
            let mut val: u128 = 0;
            for j in (0..a.len as usize).rev() {
                let p = st.ld(a.start as usize + j);
                let narrowed = if is_min {
                    and_words(&cand, &not_words(&p))
                } else {
                    and_words(&cand, &p)
                };
                let have = narrowed.iter().any(|&w| w != 0);
                if have {
                    cand = narrowed;
                    if !is_min {
                        val |= 1 << j;
                    }
                } else if is_min {
                    val |= 1 << j;
                }
            }
            reduce_out.push(val);
        }
        Opcode::ColumnTransform => {
            // data movement only; the mask column value is preserved
        }
    }
}

/// Run a program's steps over a crossbar batch (native engine). One
/// [`Scratch`] arena serves the whole batch — callers running shards on
/// worker threads get one arena per shard.
pub fn exec_steps_native(states: &mut [XbarState], steps: &[Step], mask_col: usize) -> ExecOutputs {
    let n_reduces = steps
        .iter()
        .filter(|s| {
            matches!(
                s.instr.op,
                Opcode::ReduceSum | Opcode::ReduceMin | Opcode::ReduceMax
            )
        })
        .count();
    debug_assert!(
        states.iter().all(|st| mask_col < st.planes.len()),
        "mask_col {mask_col} out of range for crossbar states"
    );
    let mut reduces = vec![Vec::with_capacity(states.len()); n_reduces];
    let mut mask_counts = Vec::with_capacity(states.len());
    let mut scratch = Scratch::new();
    for st in states.iter_mut() {
        let mut out = Vec::with_capacity(n_reduces);
        for step in steps {
            exec_instr(st, &step.instr, &mut out, &mut scratch);
        }
        for (i, v) in out.into_iter().enumerate() {
            reduces[i].push(v);
        }
        mask_counts.push(st.popcount_col(mask_col));
    }
    ExecOutputs {
        reduces,
        mask_counts,
        ..ExecOutputs::default()
    }
}

/// Run a program over a shard of *shared* crossbar states without
/// mutating them: each crossbar gets a [`SnapshotView`] (data columns
/// read through, compute columns in private zeroed scratch). This is the
/// concurrent read path — any number of threads may run programs over
/// the same `&[XbarState]` simultaneously.
///
/// `seed_masks`, when present, holds one pre-computed filter-mask plane
/// per crossbar of the shard (a shared-scan transplant); it is stored
/// into `mask_col` before the steps run, so callers pass the program's
/// suffix steps. Returns the outputs plus the final mask plane of every
/// crossbar (for capture into the scan cache).
///
/// `skip`, when present, is the shard's slice of a zone-map skip bitmap
/// ([`crate::query::opt::prune::skip_bitmap`]): flagged crossbars are
/// never interpreted — their mask is provably all-zero, and because the
/// compiler masks (or adjusts) every value expression, their outputs are
/// those of an all-zero crossbar, computed once lazily and replicated.
/// `sc`, when present, is the program's short-circuit schedule
/// ([`crate::query::opt::prune::short_circuit`]): after each scheduled
/// check step, an all-zero mask plane abandons the rest of the filter
/// prefix and resumes at the suffix. Both are pure execution shortcuts —
/// outputs stay bit-identical, only the skip counters observe them.
#[allow(clippy::too_many_arguments)]
pub(crate) fn exec_steps_snapshot(
    states: &[XbarState],
    compute_base: usize,
    steps: &[Step],
    mask_col: usize,
    seed_masks: Option<&[[u64; WORDS]]>,
    skip: Option<&[bool]>,
    sc: Option<&ShortCircuit>,
) -> (ExecOutputs, Vec<[u64; WORDS]>) {
    let n_reduces = steps
        .iter()
        .filter(|s| {
            matches!(
                s.instr.op,
                Opcode::ReduceSum | Opcode::ReduceMin | Opcode::ReduceMax
            )
        })
        .count();
    debug_assert!(
        states.iter().all(|st| mask_col < st.planes.len()),
        "mask_col {mask_col} out of range for crossbar states"
    );
    debug_assert!(seed_masks.is_none_or(|s| s.len() == states.len()));
    debug_assert!(skip.is_none_or(|s| s.len() == states.len()));
    let check_at: Vec<bool> = match sc {
        Some(sc) => {
            debug_assert!(sc.resume <= steps.len());
            let mut t = vec![false; steps.len()];
            for &k in &sc.checks {
                t[k] = true;
            }
            t
        }
        None => Vec::new(),
    };
    let mut reduces = vec![Vec::with_capacity(states.len()); n_reduces];
    let mut mask_counts = Vec::with_capacity(states.len());
    let mut mask_planes = Vec::with_capacity(states.len());
    let mut scratch = Scratch::new();
    let mut shards_skipped = 0u64;
    let mut steps_short_circuited = 0u64;
    // canonical outputs of a zone-pruned crossbar, computed lazily on
    // the first skip by running the program once over an all-zero
    // crossbar: the zone proof says the real mask is all-zero, and every
    // reduce value is mask-determined (the compiler masks or adjusts
    // non-selected rows), so the all-zero run is bit-identical to
    // executing in place.
    let mut skipped_outs: Option<Vec<u128>> = None;
    for (x, data) in states.iter().enumerate() {
        if skip.is_some_and(|s| s[x]) {
            let outs = skipped_outs.get_or_insert_with(|| {
                let zero = XbarState::new(data.planes.len());
                let mut view = SnapshotView::new(&zero, compute_base);
                let mut out = Vec::with_capacity(n_reduces);
                for step in steps {
                    exec_instr_on(&mut view, &step.instr, &mut out, &mut scratch);
                }
                debug_assert!(
                    is_zero_words(&view.ld(mask_col)),
                    "skip bitmap flagged a program whose mask is not zero on an all-zero crossbar"
                );
                out
            });
            for (i, &v) in outs.iter().enumerate() {
                reduces[i].push(v);
            }
            mask_counts.push(0);
            mask_planes.push([0u64; WORDS]);
            shards_skipped += 1;
            continue;
        }
        let mut view = SnapshotView::new(data, compute_base);
        if let Some(seeds) = seed_masks {
            view.st(mask_col, seeds[x]);
        }
        let mut out = Vec::with_capacity(n_reduces);
        let mut k = 0;
        while k < steps.len() {
            exec_instr_on(&mut view, &steps[k].instr, &mut out, &mut scratch);
            if let Some(sc) = sc {
                if check_at[k] && is_zero_words(&view.ld(mask_col)) {
                    steps_short_circuited += (sc.resume - k - 1) as u64;
                    k = sc.resume;
                    continue;
                }
            }
            k += 1;
        }
        for (i, v) in out.into_iter().enumerate() {
            reduces[i].push(v);
        }
        let m = view.ld(mask_col);
        mask_counts.push(popcount_words(&m));
        mask_planes.push(m);
    }
    (
        ExecOutputs {
            reduces,
            mask_counts,
            shards_skipped,
            steps_short_circuited,
        },
        mask_planes,
    )
}

/// Run a *fused* multi-query scan prefix over a shard of shared crossbar
/// states and capture one mask plane per member query.
///
/// `steps` is the single program emitted by
/// [`crate::query::opt::fusion::fuse`]: the union of N queries' filter
/// prefixes with common subexpressions computed once. Fused prefixes are
/// side-effect free by construction (the fusion safety analysis rejects
/// reduces and column-transforms), so the only outputs are the planes of
/// `mask_cols` — element `[q][x]` is query `q`'s filter mask on crossbar
/// `x`, byte-identical to what running query `q`'s own prefix through
/// [`exec_steps_snapshot`] would have produced.
pub(crate) fn exec_steps_fused(
    states: &[XbarState],
    compute_base: usize,
    steps: &[Step],
    mask_cols: &[usize],
) -> Vec<Vec<[u64; WORDS]>> {
    debug_assert!(
        steps.iter().all(|s| !matches!(
            s.instr.op,
            Opcode::ReduceSum | Opcode::ReduceMin | Opcode::ReduceMax
        )),
        "fused scan prefixes are side-effect free"
    );
    let mut planes = vec![Vec::with_capacity(states.len()); mask_cols.len()];
    let mut scratch = Scratch::new();
    let mut sink = Vec::new();
    for data in states {
        let mut view = SnapshotView::new(data, compute_base);
        for step in steps {
            exec_instr_on(&mut view, &step.instr, &mut sink, &mut scratch);
        }
        for (q, &mc) in mask_cols.iter().enumerate() {
            planes[q].push(view.ld(mc));
        }
    }
    planes
}

// --- word helpers -----------------------------------------------------------
//
// All plane-wide boolean algebra goes through the explicit u64x4 lane
// primitives in [`crate::util::bits`]: each 16-word plane is 4 chunks of 4
// lanes, and every chunk expression is a fixed-width branch-free vector op.

#[inline]
fn not_words(a: &[u64; WORDS]) -> [u64; WORDS] {
    let mut r = [0u64; WORDS];
    for c in 0..WORD_CHUNKS {
        store_lanes(&mut r, c, vnot(load_lanes(a, c)));
    }
    r
}

#[inline]
fn and_words(a: &[u64; WORDS], b: &[u64; WORDS]) -> [u64; WORDS] {
    let mut r = [0u64; WORDS];
    for c in 0..WORD_CHUNKS {
        store_lanes(&mut r, c, vand(load_lanes(a, c), load_lanes(b, c)));
    }
    r
}

#[inline]
fn or_words(a: &[u64; WORDS], b: &[u64; WORDS]) -> [u64; WORDS] {
    let mut r = [0u64; WORDS];
    for c in 0..WORD_CHUNKS {
        store_lanes(&mut r, c, vor(load_lanes(a, c), load_lanes(b, c)));
    }
    r
}

#[inline]
fn full_add(
    a: &[u64; WORDS],
    b: &[u64; WORDS],
    c: &[u64; WORDS],
) -> ([u64; WORDS], [u64; WORDS]) {
    let mut s = [0u64; WORDS];
    let mut co = [0u64; WORDS];
    for ch in 0..WORD_CHUNKS {
        let (va, vb, vc) = (load_lanes(a, ch), load_lanes(b, ch), load_lanes(c, ch));
        let axb = vxor(va, vb);
        store_lanes(&mut s, ch, vxor(axb, vc));
        store_lanes(&mut co, ch, vor(vand(va, vb), vand(vc, axb)));
    }
    (s, co)
}

#[inline]
fn plane_or_zero<P: Planes>(st: &P, r: ColRange, i: usize) -> [u64; WORDS] {
    if i < r.len as usize {
        st.ld(r.start as usize + i)
    } else {
        [0u64; WORDS]
    }
}

/// MSB-first compare of an attribute range against an immediate.
///
/// Per the ISA contract ([`crate::pim::isa`]), the control path examines
/// only the low `a.len` bits of `imm`: a wider immediate compares as
/// `imm mod 2^a.len`. The query compiler canonicalizes out-of-range
/// immediates to Set/Reset before they reach the engine
/// (`lower_cmp_imm`), so compiled programs never rely on the truncation.
fn cmp_imm_planes<P: Planes>(st: &P, a: ColRange, imm: u64) -> ([u64; WORDS], [u64; WORDS]) {
    let mut eq = [u64::MAX; WORDS];
    let mut lt = [0u64; WORDS];
    for i in (0..a.len as usize).rev() {
        let p = st.ld(a.start as usize + i);
        // branch on the immediate bit once per plane, then run a
        // branch-free chunked lane loop over the 1024 rows
        if (imm >> i) & 1 == 1 {
            for c in 0..WORD_CHUNKS {
                let (vp, ve) = (load_lanes(&p, c), load_lanes(&eq, c));
                store_lanes(&mut lt, c, vor(load_lanes(&lt, c), vand(ve, vnot(vp))));
                store_lanes(&mut eq, c, vand(ve, vp));
            }
        } else {
            for c in 0..WORD_CHUNKS {
                store_lanes(&mut eq, c, vand(load_lanes(&eq, c), vnot(load_lanes(&p, c))));
            }
        }
    }
    (eq, lt)
}

fn cmp_cols_planes<P: Planes>(st: &P, a: ColRange, b: ColRange) -> ([u64; WORDS], [u64; WORDS]) {
    let mut eq = [u64::MAX; WORDS];
    let mut lt = [0u64; WORDS];
    for i in (0..a.len as usize).rev() {
        let pa = st.ld(a.start as usize + i);
        let pb = plane_or_zero(st, b, i);
        for c in 0..WORD_CHUNKS {
            let (va, vb) = (load_lanes(&pa, c), load_lanes(&pb, c));
            let ve = load_lanes(&eq, c);
            store_lanes(&mut lt, c, vor(load_lanes(&lt, c), vand(vand(ve, vnot(va)), vb)));
            store_lanes(&mut eq, c, vand(ve, vnot(vxor(va, vb))));
        }
    }
    (eq, lt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::endurance::OpCategory;
    use crate::util::proptest::check;

    fn step(instr: PimInstruction) -> Step {
        Step {
            instr,
            category: OpCategory::Filter,
        }
    }

    /// One-shot `exec_instr` with a throwaway scratch arena.
    fn run(st: &mut XbarState, instr: &PimInstruction, out: &mut Vec<u128>) {
        exec_instr(st, instr, out, &mut Scratch::new());
    }

    fn load_values(vals: &[u64], start: usize, bits: usize, st: &mut XbarState) {
        for (row, &v) in vals.iter().enumerate() {
            for b in 0..bits {
                if (v >> b) & 1 == 1 {
                    st.set_bit(start + b, row, true);
                }
            }
        }
    }

    #[test]
    fn cmp_imm_all_ops() {
        check("engine-cmp-imm", 40, |g| {
            let bits = g.usize(1, 24);
            let vals = g.vec_u64(64, 0, (1 << bits) - 1);
            let imm = *g.pick(&vals); // guarantee eq hits
            let mut st = XbarState::new(64);
            load_values(&vals, 0, bits, &mut st);
            let a = ColRange::new(0, bits);
            for (op, oracle) in [
                (Opcode::EqImm, Box::new(|v: u64| v == imm) as Box<dyn Fn(u64) -> bool>),
                (Opcode::NeImm, Box::new(|v| v != imm)),
                (Opcode::LtImm, Box::new(|v| v < imm)),
                (Opcode::GtImm, Box::new(|v| v > imm)),
            ] {
                let mut out = Vec::new();
                run(
                    &mut st,
                    &PimInstruction::with_imm(op, a, ColRange::new(40, 1), imm),
                    &mut out,
                );
                for (row, &v) in vals.iter().enumerate() {
                    assert_eq!(
                        st.value_at(row, ColRange::new(40, 1)) == 1,
                        oracle(v),
                        "{op:?} row {row} v {v} imm {imm}"
                    );
                }
            }
        });
    }

    #[test]
    fn add_mul_match_integer_semantics() {
        check("engine-arith", 30, |g| {
            let bits = g.usize(1, 16);
            let a_vals = g.vec_u64(100, 0, (1 << bits) - 1);
            let b_vals = g.vec_u64(100, 0, (1 << bits) - 1);
            let mut st = XbarState::new(128);
            load_values(&a_vals, 0, bits, &mut st);
            let b_start = 20;
            load_values(&b_vals, b_start, bits, &mut st);
            // Add into 2n-wide dst
            let dst = ColRange::new(44, bits + 1);
            let mut out = Vec::new();
            run(
                &mut st,
                &PimInstruction::binary(
                    Opcode::Add,
                    ColRange::new(0, bits),
                    ColRange::new(b_start, bits),
                    dst,
                ),
                &mut out,
            );
            for row in 0..100 {
                assert_eq!(st.value_at(row, dst), a_vals[row] + b_vals[row]);
            }
            // Mul into (n+m)-wide dst
            let dstm = ColRange::new(70, 2 * bits);
            run(
                &mut st,
                &PimInstruction::binary(
                    Opcode::Mul,
                    ColRange::new(0, bits),
                    ColRange::new(b_start, bits),
                    dstm,
                ),
                &mut out,
            );
            for row in 0..100 {
                assert_eq!(st.value_at(row, dstm), a_vals[row] * b_vals[row]);
            }
        });
    }

    #[test]
    fn widening_add_imm_propagates_carry_and_zero_extends() {
        // Regression: AddImm used to iterate 0..a.len, so a widening add
        // dropped the final carry and left stale planes above a.len.
        check("engine-addimm-widen", 30, |g| {
            let src_bits = g.usize(1, 12);
            let dst_bits = src_bits + g.usize(1, 8);
            let imm = g.u64(0, (1 << dst_bits) - 1);
            let vals = g.vec_u64(200, 0, (1 << src_bits) - 1);
            let mut st = XbarState::new(96);
            load_values(&vals, 0, src_bits, &mut st);
            // poison the destination with stale all-ones planes
            let dst = ColRange::new(40, dst_bits);
            let mut out = Vec::new();
            run(&mut st, &PimInstruction::unary(Opcode::Set, dst, dst), &mut out);
            run(
                &mut st,
                &PimInstruction::with_imm(
                    Opcode::AddImm,
                    ColRange::new(0, src_bits),
                    dst,
                    imm,
                ),
                &mut out,
            );
            let modw = 1u64 << dst_bits;
            for (row, &v) in vals.iter().enumerate() {
                assert_eq!(
                    st.value_at(row, dst),
                    (v + imm) % modw,
                    "row {row}: {v} + {imm} (src {src_bits}b dst {dst_bits}b)"
                );
            }
        });
    }

    #[test]
    fn add_imm_carry_out_reaches_top_plane() {
        // The sharpest form of the bug: all-ones source + imm 1 must carry
        // into the (dst_bits-1) plane, which only the widened loop writes.
        let bits = 8;
        let vals = vec![(1u64 << bits) - 1; 64];
        let mut st = XbarState::new(64);
        load_values(&vals, 0, bits, &mut st);
        let dst = ColRange::new(30, bits + 1);
        let mut out = Vec::new();
        run(
            &mut st,
            &PimInstruction::with_imm(Opcode::AddImm, ColRange::new(0, bits), dst, 1),
            &mut out,
        );
        for row in 0..64 {
            assert_eq!(st.value_at(row, dst), 1 << bits, "row {row}");
        }
    }

    #[test]
    fn and_broadcast_masks_values() {
        let vals: Vec<u64> = (0..64).map(|i| i * 3 + 1).collect();
        let mut st = XbarState::new(64);
        load_values(&vals, 0, 10, &mut st);
        // mask column: even rows selected
        for row in (0..64).step_by(2) {
            st.set_bit(30, row, true);
        }
        let mut out = Vec::new();
        run(
            &mut st,
            &PimInstruction::binary(
                Opcode::And,
                ColRange::new(0, 10),
                ColRange::new(30, 1),
                ColRange::new(40, 10),
            ),
            &mut out,
        );
        for (row, &v) in vals.iter().enumerate() {
            let want = if row % 2 == 0 { v } else { 0 };
            assert_eq!(st.value_at(row, ColRange::new(40, 10)), want);
        }
    }

    #[test]
    fn reduce_sum_counts_masked_values() {
        let vals: Vec<u64> = (0..200).map(|i| i as u64).collect();
        let mut st = XbarState::new(64);
        load_values(&vals, 0, 9, &mut st);
        let mut out = Vec::new();
        run(
            &mut st,
            &PimInstruction::unary(
                Opcode::ReduceSum,
                ColRange::new(0, 9),
                ColRange::new(0, 9),
            ),
            &mut out,
        );
        assert_eq!(out[0], (0..200u128).sum());
    }

    #[test]
    fn reduce_min_max() {
        check("engine-minmax", 20, |g| {
            let vals = g.vec_u64(300, 1, 1 << 20);
            let mut st = XbarState::new(64);
            load_values(&vals, 0, 21, &mut st);
            // unoccupied rows (300..1024) are zero -> min must see them;
            // emulate the compiler's MIN adjustment by OR-ing all-ones into
            // empty rows: here just check MAX (zeros are identity)
            let mut out = Vec::new();
            run(
                &mut st,
                &PimInstruction::unary(
                    Opcode::ReduceMax,
                    ColRange::new(0, 21),
                    ColRange::new(0, 21),
                ),
                &mut out,
            );
            assert_eq!(out[0], *vals.iter().max().unwrap() as u128);
        });
    }

    #[test]
    fn snapshot_exec_matches_native_and_leaves_data_untouched() {
        check("engine-snapshot-vs-native", 25, |g| {
            let bits = g.usize(1, 10);
            let imm = g.u64(0, (1 << bits) - 1);
            let n_states = g.usize(1, 3);
            let compute_base = 16;
            let mut native: Vec<XbarState> = Vec::new();
            for _ in 0..n_states {
                let vals = g.vec_u64(XBAR_ROWS, 0, (1 << bits) - 1);
                let mut st = XbarState::new(48);
                load_values(&vals, 0, bits, &mut st);
                native.push(st);
            }
            let shared = native.clone();
            let mask_col = 20;
            let steps = vec![
                step(PimInstruction::with_imm(
                    Opcode::LtImm,
                    ColRange::new(0, bits),
                    ColRange::new(mask_col, 1),
                    imm,
                )),
                step(PimInstruction::binary(
                    Opcode::And,
                    ColRange::new(0, bits),
                    ColRange::new(mask_col, 1),
                    ColRange::new(24, bits),
                )),
                step(PimInstruction::unary(
                    Opcode::ReduceSum,
                    ColRange::new(24, bits),
                    ColRange::new(24, bits),
                )),
            ];
            let want = exec_steps_native(&mut native, &steps, mask_col);
            let (got, masks) =
                exec_steps_snapshot(&shared, compute_base, &steps, mask_col, None, None, None);
            assert_eq!(got.reduces, want.reduces);
            assert_eq!(got.mask_counts, want.mask_counts);
            // the captured mask planes equal the in-place result planes
            for (x, m) in masks.iter().enumerate() {
                assert_eq!(*m, native[x].planes[mask_col]);
            }
            // the shared states were never written: data columns pristine,
            // and the compute area still all-zero
            for (x, st) in shared.iter().enumerate() {
                for c in 0..st.planes.len() {
                    if c < compute_base {
                        // programs write compute columns only, so the
                        // native run's data area is the pristine one
                        assert_eq!(st.planes[c], native[x].planes[c], "data col {c}");
                    } else {
                        assert_eq!(st.planes[c], [0u64; WORDS], "compute col {c}");
                    }
                }
            }
            // replay: seeding the captured masks and running only the
            // suffix reproduces the full-program outputs
            let (replayed, masks2) = exec_steps_snapshot(
                &shared,
                compute_base,
                &steps[1..],
                mask_col,
                Some(&masks),
                None,
                None,
            );
            assert_eq!(replayed.reduces, want.reduces);
            assert_eq!(replayed.mask_counts, want.mask_counts);
            assert_eq!(masks2, masks);
        });
    }

    #[test]
    fn fused_exec_matches_per_query_snapshot_runs() {
        check("engine-fused-vs-snapshot", 25, |g| {
            let bits = g.usize(1, 10);
            let lo = g.u64(0, (1 << bits) - 1);
            let hi = g.u64(0, (1 << bits) - 1);
            let n_states = g.usize(1, 3);
            let compute_base = 16;
            let mut states: Vec<XbarState> = Vec::new();
            for _ in 0..n_states {
                let vals = g.vec_u64(XBAR_ROWS, 0, (1 << bits) - 1);
                let mut st = XbarState::new(48);
                load_values(&vals, 0, bits, &mut st);
                states.push(st);
            }
            let a = ColRange::new(0, bits);
            // two queries sharing the LtImm subexpression: q0's mask is
            // the raw compare, q1 ANDs it with an EqImm
            let q0 = vec![step(PimInstruction::with_imm(
                Opcode::LtImm,
                a,
                ColRange::new(20, 1),
                lo,
            ))];
            let q1 = vec![
                step(PimInstruction::with_imm(Opcode::LtImm, a, ColRange::new(20, 1), lo)),
                step(PimInstruction::with_imm(Opcode::EqImm, a, ColRange::new(21, 1), hi)),
                step(PimInstruction::binary(
                    Opcode::And,
                    ColRange::new(21, 1),
                    ColRange::new(20, 1),
                    ColRange::new(22, 1),
                )),
            ];
            // the hand-fused union: shared LtImm once, then q1's extras
            let fused = vec![q1[0].clone(), q1[1].clone(), q1[2].clone()];
            let got = exec_steps_fused(&states, compute_base, &fused, &[20, 22]);
            let (_, want0) = exec_steps_snapshot(&states, compute_base, &q0, 20, None, None, None);
            let (_, want1) = exec_steps_snapshot(&states, compute_base, &q1, 22, None, None, None);
            assert_eq!(got[0], want0);
            assert_eq!(got[1], want1);
        });
    }

    #[test]
    fn exec_steps_collects_reduces_per_xbar() {
        let mut states = vec![XbarState::new(32), XbarState::new(32)];
        load_values(&[1, 2, 3], 0, 4, &mut states[0]);
        load_values(&[10, 20], 0, 6, &mut states[1]);
        let steps = vec![
            step(PimInstruction::unary(
                Opcode::Set,
                ColRange::new(20, 1),
                ColRange::new(20, 1),
            )),
            step(PimInstruction::unary(
                Opcode::ReduceSum,
                ColRange::new(0, 8),
                ColRange::new(0, 8),
            )),
        ];
        let out = exec_steps_native(&mut states, &steps, 20);
        assert_eq!(out.reduces[0], vec![6, 30]);
        assert_eq!(out.combined(0), 36);
        assert_eq!(out.mask_counts, vec![1024, 1024]); // Set column
    }
}
