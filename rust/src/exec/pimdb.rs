//! The PIMDB engine (paper §5.4): compiles a query, executes it
//! functionally over the crossbar states, and runs the timing / energy /
//! power / endurance simulation at the report scale factor.
//!
//! Execution structure follows the paper: the work of each relation is
//! split among `exec_threads` worker threads by huge-pages; each thread
//! runs a computation phase (PIM requests to each of its pages, pipelined
//! across pages, serialized per page) followed by a read phase (result
//! read-out), with memory fences between phases.
//!
//! ## Host-parallel functional execution
//!
//! The *functional* interpretation of the crossbar states is sharded and
//! executed on a host worker pool ([`crate::exec::plan`], sized by
//! `SystemConfig::parallelism`; 0 = auto). Crossbars are independent, so
//! outputs are bit-identical to the serial interpreter for every shard
//! and thread count. The *simulated* timing/energy/endurance metrics
//! depend only on the paper's model (`exec_threads` et al.), never on the
//! host parallelism: cycle accounting is derived per program from the
//! instruction stream alone (execution-order independent) and combined
//! with a commutative merge — totals are bit-identical too.
//!
//! [`PimSession::run_queries`] is the batched entry point: queries whose
//! relation sets are disjoint execute concurrently over the same shard
//! pool (a wave), while queries sharing a relation serialize (they share
//! the relation's crossbar compute area). This is the serving-path shape:
//! one resident database copy, many independent queries in flight.

use std::collections::{BTreeMap, BTreeSet};

use crate::config::SystemConfig;
use crate::db::dbgen::Database;
use crate::db::freerows::FreeRowMap;
use crate::db::layout::{DbLayout, RelationLayout};
use crate::db::schema::RelId;
use crate::error::PimdbError;
use crate::exec::engine::{self, ExecOutputs, XbarState};
use crate::exec::metrics::{
    CycleCounts, DmlResult, GroupOutput, OptSummary, QueryMetrics, QueryOutput, RunReport,
};
use crate::exec::plan::{self, ExecPlan, ShardTask};
use crate::host;
use crate::pim::controller::{cost, write_profile, InstructionCost};
use crate::pim::endurance::{EnduranceTracker, OpCategory};
use crate::pim::energy::EnergyLedger;
use crate::pim::isa::ColRange;
use crate::pim::module::{MediaScheduler, ReqKind, Request};
use crate::pim::power::{self, PowerTrace};
use crate::pim::timing::{self, Timing};
use crate::query::ast::{AggKind, Dml, Query, QueryKind};
use crate::query::compiler::{
    compile_dml, CompileError, CompiledDml, CompiledDmlOp, CompiledRelQuery, Compiler, ReadKind,
    Step,
};
use crate::query::opt;
use crate::util::bits::{WORDS, WORD_BITS, XBAR_ROWS};

/// Which functional backend computes instruction semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-rust bit-plane interpreter.
    Native,
    /// AOT-compiled XLA executables on the PJRT CPU client (the Pallas
    /// kernel artifacts from `make artifacts`).
    Pjrt,
}

/// Host-side per-request issue gap (store-class instruction + fence
/// amortization) in picoseconds.
const ISSUE_GAP_PS: u64 = 10_000;

/// A PIM session: the database copy loaded into the PIM modules once and
/// queried repeatedly (paper §4: "the database copy is constructed offline
/// once and then used for query execution" — query execution does not
/// modify the data columns; intermediate results live in the compute
/// area, which the session clears between queries).
///
/// **Internal implementation detail.** The supported embedding surface is
/// the owned, shareable [`crate::api::Pimdb`] handle (`open` / `prepare` /
/// `execute`), which adds a plan cache, typed result cursors and
/// `&self`-concurrent execution on top of the same engine. `PimSession`
/// remains exported only so the differential suite
/// (`tests/api_equivalence.rs`) can pin the new facade bit-for-bit against
/// this original path; it borrows both the config and the database and
/// serializes all execution through `&mut self`.
pub struct PimSession<'a> {
    /// The system configuration the session runs under.
    pub cfg: &'a SystemConfig,
    db: &'a Database,
    layout: DbLayout,
    states: BTreeMap<RelId, Vec<XbarState>>,
    /// Row liveness + wear maps, created on first DML per relation. The
    /// session mutates only its PIM copy (`db` stays the pristine load
    /// image); the supported mutable surface is
    /// [`crate::api::Pimdb::execute_dml`].
    freerows: BTreeMap<RelId, FreeRowMap>,
}

/// One program of one query inside a wave (all relations of a wave are
/// distinct, so each program owns its relation's states exclusively).
struct WaveProg {
    qi: usize,
    ci: usize,
    rel: RelId,
    compute_base: usize,
}

/// Zero the crossbar compute area (the paper's read phase frees it; data
/// columns are never modified by query execution).
pub(crate) fn clear_compute(states: &mut [XbarState], compute_base: usize) {
    for st in states.iter_mut() {
        for p in &mut st.planes[compute_base..] {
            *p = [0u64; WORDS];
        }
    }
}

impl<'a> PimSession<'a> {
    /// Lay out `db` over the PIM modules (states load lazily per relation).
    pub fn new(cfg: &'a SystemConfig, db: &'a Database) -> Result<Self, PimdbError> {
        Ok(PimSession {
            cfg,
            db,
            layout: DbLayout::build(cfg, &|r| db.rel(r).records as u64)?,
            states: Default::default(),
            freerows: Default::default(),
        })
    }

    /// The database's PIM layout (page placement, column slots).
    pub fn layout(&self) -> &DbLayout {
        &self.layout
    }

    fn states_for(&mut self, rel: RelId) -> &mut Vec<XbarState> {
        let cfg = self.cfg;
        let db = self.db;
        let rl = self.layout.rel(rel);
        self.states.entry(rel).or_insert_with(|| {
            engine::load_states(db.rel(rel), rl, cfg.xbar_cols, 0..db.rel(rel).records)
        })
    }

    /// Run one query against the loaded database copy.
    pub fn run_query(
        &mut self,
        q: &Query,
        engine_kind: EngineKind,
    ) -> Result<RunReport, PimdbError> {
        let mut reports = self.run_queries(std::slice::from_ref(q), engine_kind)?;
        Ok(reports.pop().expect("one report"))
    }

    /// Batched entry point: run several queries against the resident
    /// database copy, pipelining them over the shard pool. Queries on
    /// disjoint relation sets execute concurrently (a *wave*); queries
    /// sharing a relation serialize between waves. Reports come back in
    /// input order, bit-identical to running the queries one by one.
    pub fn run_queries(
        &mut self,
        queries: &[Query],
        engine_kind: EngineKind,
    ) -> Result<Vec<RunReport>, PimdbError> {
        let exec_plan = ExecPlan::for_config(self.cfg);

        // --- compile everything up front (errors before any execution) ---
        let compiled_all: Vec<Vec<CompiledRelQuery>> = queries
            .iter()
            .map(|q| {
                q.rels
                    .iter()
                    .map(|rq| Compiler::compile(rq, self.layout.rel(rq.rel), self.cfg.xbar_cols))
                    .collect::<Result<_, CompileError>>()
            })
            .collect::<Result<_, CompileError>>()?;

        // --- optimizer pass pipeline (waves execute optimized programs) ---
        let mut opt_summaries: Vec<OptSummary> = Vec::with_capacity(compiled_all.len());
        let compiled_all: Vec<Vec<CompiledRelQuery>> = compiled_all
            .into_iter()
            .map(|compiled| {
                let mut sum = opt::OptStats::default();
                let out = compiled
                    .iter()
                    .map(|c| {
                        let (o, st) =
                            opt::optimize(c, self.cfg.opt_level, self.cfg.xbar_rows);
                        sum.merge(&st);
                        o
                    })
                    .collect();
                opt_summaries.push(OptSummary::from(sum));
                out
            })
            .collect();

        // --- materialize every touched relation once ----------------------
        for compiled in &compiled_all {
            for c in compiled {
                self.states_for(c.rel);
            }
        }

        // --- wave schedule -------------------------------------------------
        // A query with a duplicated relation (two programs on the same
        // crossbars) runs alone and sequentially — its programs share the
        // relation's compute area.
        let has_dup: Vec<bool> = compiled_all
            .iter()
            .map(|compiled| {
                let mut seen = BTreeSet::new();
                !compiled.iter().all(|c| seen.insert(c.rel))
            })
            .collect();
        let mut waves: Vec<Vec<usize>> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        let mut used: BTreeSet<RelId> = BTreeSet::new();
        for qi in 0..queries.len() {
            let rels: Vec<RelId> = compiled_all[qi].iter().map(|c| c.rel).collect();
            if has_dup[qi] {
                if !cur.is_empty() {
                    waves.push(std::mem::take(&mut cur));
                    used.clear();
                }
                waves.push(vec![qi]);
                continue;
            }
            if rels.iter().any(|r| used.contains(r)) {
                waves.push(std::mem::take(&mut cur));
                used.clear();
            }
            cur.push(qi);
            used.extend(rels);
        }
        if !cur.is_empty() {
            waves.push(cur);
        }

        // --- execute wave by wave -----------------------------------------
        let mut outputs: BTreeMap<(usize, usize), ExecOutputs> = BTreeMap::new();
        for wave in waves {
            if wave.len() == 1 && has_dup[wave[0]] {
                // sequential fallback: programs reuse the compute area.
                // States are moved out for the duration of each program so
                // a backend error drops them (same as the wave path) —
                // never leave a half-mutated compute area resident.
                let qi = wave[0];
                for (ci, c) in compiled_all[qi].iter().enumerate() {
                    let compute_base = self.layout.rel(c.rel).compute_base;
                    let mut states = self.states.remove(&c.rel).expect("preloaded above");
                    let out = plan::exec_steps_sharded(
                        &mut states,
                        &c.steps,
                        c.mask_col,
                        engine_kind,
                        &exec_plan,
                    )?;
                    clear_compute(&mut states, compute_base);
                    self.states.insert(c.rel, states);
                    outputs.insert((qi, ci), out);
                }
                continue;
            }

            let layout = &self.layout;
            let progs: Vec<WaveProg> = wave
                .iter()
                .flat_map(|&qi| {
                    compiled_all[qi].iter().enumerate().map(move |(ci, c)| WaveProg {
                        qi,
                        ci,
                        rel: c.rel,
                        compute_base: layout.rel(c.rel).compute_base,
                    })
                })
                .collect();

            // move each program's states out of the session map; on error
            // the moved states are dropped and lazily reloaded clean later
            let mut prog_states: Vec<Vec<XbarState>> = progs
                .iter()
                .map(|p| self.states.remove(&p.rel).expect("preloaded above"))
                .collect();

            let mut tasks: Vec<ShardTask<'_>> = Vec::new();
            for (key, (p, states)) in progs.iter().zip(prog_states.iter_mut()).enumerate() {
                let c = &compiled_all[p.qi][p.ci];
                plan::push_shard_tasks(
                    &mut tasks,
                    key,
                    states,
                    &c.steps,
                    c.mask_col,
                    engine_kind,
                    &exec_plan,
                );
            }
            let merged = plan::run_tasks(tasks, progs.len(), exec_plan.parallelism)?;

            for (p, states) in progs.iter().zip(prog_states.iter_mut()) {
                clear_compute(states, p.compute_base);
            }
            for ((p, states), out) in progs.iter().zip(prog_states).zip(merged) {
                self.states.insert(p.rel, states);
                outputs.insert((p.qi, p.ci), out);
            }
        }

        // --- assemble outputs + run the timing/energy simulation -----------
        let mut reports = Vec::with_capacity(queries.len());
        for (qi, q) in queries.iter().enumerate() {
            let compiled = &compiled_all[qi];
            // relations with a free-row map (i.e. ever mutated) accumulate
            // this query's per-row write profile into the persistent wear
            // counters the endurance-aware allocator consults
            for c in compiled.iter() {
                if let Some(free) = self.freerows.get_mut(&c.rel) {
                    charge_wear(free, &c.steps, self.cfg.xbar_cols);
                }
            }
            let outs: Vec<ExecOutputs> = (0..compiled.len())
                .map(|ci| outputs.remove(&(qi, ci)).expect("executed above"))
                .collect();
            let output = assemble_output(q, compiled, &outs);
            let mut metrics = simulate(self.cfg, q, compiled, &self.layout);
            metrics.inter_cells = compiled
                .iter()
                .map(|c| c.peak_inter_cells)
                .max()
                .unwrap_or(0);
            metrics.opt = opt_summaries[qi];
            reports.push(RunReport {
                query: q.name,
                metrics,
                output,
            });
        }
        Ok(reports)
    }

    /// Execute one DML statement against the session's PIM copy: compile
    /// it, run the filter + in-place mutation (UPDATE/DELETE) or the
    /// endurance-aware row write (INSERT), and report rows affected, the
    /// wear delta and the simulated application cost.
    ///
    /// The mutation applies to the *PIM copy only* — the session borrows
    /// its [`Database`] immutably and never rewrites the load image. Use
    /// [`crate::exec::baseline::apply_dml`] on a database copy to keep a
    /// host-side mirror for differential comparisons.
    pub fn run_dml(
        &mut self,
        dml: &Dml,
        engine_kind: EngineKind,
    ) -> Result<DmlResult, PimdbError> {
        let rel = dml.rel();
        if !rel.in_pim() {
            // AST-built statements bypass the PQL lowering's diagnostic:
            // return the typed error instead of a layout panic
            return Err(CompileError::NotPimResident { rel }.into());
        }
        let compiled = compile_dml(dml, self.layout.rel(rel), self.cfg.xbar_cols)?;
        self.states_for(rel);
        let exec_plan = ExecPlan::for_config(self.cfg);
        let cfg = self.cfg;
        let mut states = self.states.remove(&rel).expect("materialized above");
        let r = self.db.rel(rel);
        let free = self.freerows.entry(rel).or_insert_with(|| {
            // shadow the load image's liveness exactly (a mutated store
            // reloads with dead slots between live ones)
            let flags: Vec<bool> = (0..r.records).map(|i| r.live(i)).collect();
            FreeRowMap::from_flags(&flags, states.len() * XBAR_ROWS, XBAR_ROWS)
        });
        let out = exec_dml_on_states(
            cfg,
            &self.layout,
            rel,
            &mut states,
            free,
            &compiled,
            engine_kind,
            &exec_plan,
        );
        if out.is_ok() {
            self.states.insert(rel, states);
        } else {
            // a failed backend may have torn the statement: drop the
            // states (lazy pristine reload) and the now-stale liveness
            // map (only reachable via backend errors; native is total)
            self.freerows.remove(&rel);
        }
        out
    }

    /// Live records currently in the PIM copy of `rel` (the load image's
    /// live count until a DML statement touches the relation).
    pub fn live_records(&self, rel: RelId) -> usize {
        self.freerows
            .get(&rel)
            .map(|f| f.live_count())
            .unwrap_or_else(|| self.db.rel(rel).live_count())
    }
}

/// Record one program's endurance write profile into a tracker (the
/// per-category split Tables 5–6 use; shared by the report simulation and
/// the persistent per-row wear accounting).
pub(crate) fn record_endurance(tr: &mut EnduranceTracker, steps: &[Step], xbar_rows: usize) {
    for s in steps {
        let profile = write_profile(&s.instr, xbar_rows);
        match s.category {
            OpCategory::AggCol | OpCategory::AggRow => {
                tr.record_split(OpCategory::AggCol, OpCategory::AggRow, &profile)
            }
            OpCategory::ColTransform => {
                tr.record_split(OpCategory::ColTransform, OpCategory::ColTransform, &profile)
            }
            cat => tr.record(cat, &profile),
        }
    }
}

/// One executed program's per-row write profile (`XBAR_ROWS` totals,
/// identical on every crossbar of the relation). The snapshot read path
/// computes this without holding any relation lock and folds it into a
/// ledger; [`charge_wear`] is the charge-immediately form.
pub(crate) fn wear_profile(steps: &[Step], xbar_cols: usize) -> Vec<u64> {
    let mut tr = EnduranceTracker::new(XBAR_ROWS, xbar_cols);
    record_endurance(&mut tr, steps, XBAR_ROWS);
    tr.row_totals()
}

/// Charge one executed program's write profile into a relation's
/// persistent wear counters — the single charging policy shared by the
/// [`crate::api::Pimdb`] facade, [`PimSession`] and the DML executor,
/// so the endurance-aware allocator sees identical heat on every path.
pub(crate) fn charge_wear(free: &mut FreeRowMap, steps: &[Step], xbar_cols: usize) {
    free.charge_profile(&wear_profile(steps, xbar_cols));
}

/// Global sim-row indices whose bit is set in `mask_col`.
fn mask_rows(states: &[XbarState], mask_col: usize) -> Vec<usize> {
    let mut rows = Vec::new();
    for (x, st) in states.iter().enumerate() {
        for (w, &word) in st.planes[mask_col].iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                rows.push(x * XBAR_ROWS + w * WORD_BITS + b);
                bits &= bits - 1;
            }
        }
    }
    rows
}

/// Simulated cost of one INSERT row write (paper §3.1 programming model:
/// the host stores the encoded record into the PIM page and flushes the
/// written cache lines so they reach the media — PIM data must not stay
/// cached — then the array commits an RRAM row write).
fn insert_metrics(cfg: &SystemConfig, row_bits: usize) -> QueryMetrics {
    let t = Timing::new(cfg);
    let bytes = (row_bits as u64).div_ceil(8);
    let lines = bytes.div_ceil(cfg.cache_block as u64);
    // channel latency + header-amortized occupancy (pim::timing), then
    // the array commits the row: bank-write occupancy, floored by the
    // RRAM write latency
    let array_ps = t
        .bank_write_ps(bytes)
        .max(cfg.rram_write_ns * timing::PS_PER_NS);
    let total_ps = t.channel_latency_ps + t.channel_occupancy_ps(bytes) + array_ps;
    let exec_time_s = total_ps as f64 * 1e-12;
    let mut pim_energy = EnergyLedger::default();
    pim_energy.add_write_bits(cfg, row_bits as u64);
    pim_energy.add_io_bytes(cfg, bytes);
    let ops_per_cell = row_bits as f64 / cfg.xbar_cols as f64;
    let executions_per_10yr = 10.0 * 365.25 * 24.0 * 3600.0 / exec_time_s.max(1e-12);
    QueryMetrics {
        exec_time_s,
        pim_time_s: array_ps as f64 * 1e-12,
        read_time_s: 0.0,
        other_time_s: 0.0,
        // uncacheable stores + flushes: every written line reaches memory
        llc_misses: lines,
        host_energy_pj: host::power::host_energy_pj(cfg, exec_time_s, exec_time_s, 1),
        dram_energy_pj: 0.0,
        pim_energy,
        cycles: CycleCounts::default(),
        inter_cells: 0,
        opt: OptSummary::default(),
        plan_cache: Default::default(),
        shards_skipped: 0,
        steps_short_circuited: 0,
        peak_chip_w: 0.0,
        avg_chip_w: 0.0,
        theoretical_chip_w: 0.0,
        ops_per_cell,
        required_endurance_10yr: ops_per_cell * executions_per_10yr,
        endurance_breakdown: [0.0; 5],
    }
}

/// Apply one compiled DML statement to a relation's crossbar states,
/// updating the free-row map (liveness + monotone wear) and returning the
/// functional effect plus simulated cost. Shared by
/// [`PimSession::run_dml`] and the [`crate::api::Pimdb`] service handle.
#[allow(clippy::too_many_arguments)]
pub(crate) fn exec_dml_on_states(
    cfg: &SystemConfig,
    layout: &DbLayout,
    rel: RelId,
    states: &mut Vec<XbarState>,
    free: &mut FreeRowMap,
    c: &CompiledDml,
    engine_kind: EngineKind,
    exec_plan: &ExecPlan,
) -> Result<DmlResult, PimdbError> {
    match &c.op {
        CompiledDmlOp::Insert {
            fields,
            valid_col,
            row_bits,
        } => {
            // endurance-aware placement: least-worn free row; a full
            // relation materializes one more (all-zero) crossbar
            let row = match free.alloc() {
                Some(r) => r,
                None => {
                    states.push(XbarState::new(cfg.xbar_cols));
                    free.grow(XBAR_ROWS);
                    free.alloc().expect("grew by a crossbar")
                }
            };
            let (x, r) = (row / XBAR_ROWS, row % XBAR_ROWS);
            for &(start, bits, value) in fields {
                states[x].write_value(r, ColRange::new(start, bits), value);
            }
            states[x].write_value(r, ColRange::new(*valid_col, 1), 1);
            free.charge_row(row, *row_bits as u64);
            let metrics = insert_metrics(cfg, *row_bits);
            Ok(DmlResult {
                rows_affected: 1,
                wear_delta: metrics.ops_per_cell,
                metrics,
            })
        }
        CompiledDmlOp::Mask {
            steps,
            mask_col,
            peak_inter_cells,
            compute_base,
            deletes,
        } => {
            let out =
                plan::exec_steps_sharded(states, steps, *mask_col, engine_kind, exec_plan)?;
            let rows_affected = out.total_selected();
            if *deletes {
                for row in mask_rows(states, *mask_col) {
                    free.release(row);
                }
            }
            clear_compute(states, *compute_base);

            // persistent per-row wear: the statement's write profile,
            // identical on every crossbar of the relation
            charge_wear(free, steps, cfg.xbar_cols);

            // simulated application cost: the statement is a filter-only
            // program (compute phase = filter + mutation writes, read
            // phase = affected-row mask read-out)
            let faux = CompiledRelQuery {
                rel,
                steps: steps.clone(),
                read: ReadKind::FilterMask,
                groups: vec![vec![]],
                outputs: vec![],
                n_reduces: 0,
                mask_col: *mask_col,
                peak_inter_cells: *peak_inter_cells,
                spans: Vec::new(),
                compute_base: *compute_base,
                valid_col: layout.rel(rel).valid_col,
            };
            let dummy = Query {
                name: "dml",
                kind: QueryKind::FilterOnly,
                rels: vec![],
            };
            let mut metrics = simulate(cfg, &dummy, std::slice::from_ref(&faux), layout);
            metrics.inter_cells = *peak_inter_cells;
            Ok(DmlResult {
                rows_affected,
                wear_delta: metrics.ops_per_cell,
                metrics,
            })
        }
    }
}

/// One-shot convenience: load + run a single query (examples, CLI `run`).
/// For repeated queries use [`PimSession`] — loading the database copy is
/// a one-time cost in the paper's model too.
pub fn run_query(
    cfg: &SystemConfig,
    db: &Database,
    q: &Query,
    engine_kind: EngineKind,
) -> Result<RunReport, PimdbError> {
    PimSession::new(cfg, db)?.run_query(q, engine_kind)
}

/// Assemble the functional result (host-side combine of per-crossbar
/// values, host division for AVG — paper §4.2).
pub(crate) fn assemble_output(
    q: &Query,
    compiled: &[CompiledRelQuery],
    outs: &[ExecOutputs],
) -> QueryOutput {
    let mut selected = Vec::new();
    let mut groups = Vec::new();
    for (c, o) in compiled.iter().zip(outs) {
        selected.push((c.rel.name(), o.total_selected()));
        if q.kind != QueryKind::Full {
            continue;
        }
        for (gi, key) in c.groups.iter().enumerate() {
            let count = c
                .outputs
                .iter()
                .find(|s| s.group == gi && matches!(s.kind, AggKind::Count | AggKind::Avg))
                .map(|s| match s.kind {
                    AggKind::Count => o.combined(s.reduce_index) as u64,
                    _ => o.combined(s.count_index.unwrap_or(s.reduce_index)) as u64,
                });
            // resolve the group's record count first: MIN/MAX over an
            // empty selection must report 0, not the adjustment sentinel
            let count = count.unwrap_or_else(|| {
                if key.is_empty() {
                    o.total_selected()
                } else {
                    0
                }
            });
            let mut values = Vec::new();
            for spec in c.outputs.iter().filter(|s| s.group == gi) {
                // host-side combine across crossbars depends on the
                // aggregate: SUM/COUNT add, MIN/MAX compare (paper §4.2:
                // only commutative+associative ops reduce in-array)
                let v = match spec.kind {
                    AggKind::Avg => {
                        let cnt = o.combined(spec.count_index.expect("avg count")) as f64;
                        if cnt > 0.0 {
                            o.combined(spec.reduce_index) as f64 / cnt
                        } else {
                            0.0
                        }
                    }
                    AggKind::Sum | AggKind::Count => o.combined(spec.reduce_index) as f64,
                    AggKind::Max if count == 0 => 0.0,
                    AggKind::Min if count == 0 => 0.0,
                    AggKind::Max => o.reduces[spec.reduce_index]
                        .iter()
                        .copied()
                        .max()
                        .unwrap_or(0) as f64,
                    AggKind::Min => o.reduces[spec.reduce_index]
                        .iter()
                        .copied()
                        .min()
                        .unwrap_or(0) as f64,
                };
                values.push((spec.label, v));
            }
            if count > 0 || key.is_empty() {
                groups.push(GroupOutput {
                    key: key.clone(),
                    values,
                    count,
                });
            }
        }
    }
    QueryOutput { selected, groups }
}

/// Read-phase bytes for report page `p` of a relation.
fn page_read_bytes(c: &CompiledRelQuery, rl: &RelationLayout, cfg: &SystemConfig, p: u64) -> u64 {
    let per_page = cfg.records_per_page();
    let recs = rl
        .records_report
        .saturating_sub(p * per_page)
        .min(per_page);
    match c.read {
        ReadKind::FilterMask => recs.div_ceil(8),
        ReadKind::Aggregates { values, bits } => {
            let xbars = recs.div_ceil(cfg.xbar_rows as u64);
            xbars * values as u64 * (bits as u64 / 8)
        }
    }
}

/// Table 5 per-crossbar cycle totals of one compiled program. The
/// instruction stream is identical on every crossbar/page, so the count
/// depends only on the program — not on how its shards were scheduled —
/// and programs combine with a commutative merge.
fn count_cycles(costs: &[(InstructionCost, OpCategory)]) -> CycleCounts {
    let mut cycles = CycleCounts::default();
    for (ic, cat) in costs {
        match cat {
            OpCategory::AggCol | OpCategory::AggRow => {
                cycles.add(OpCategory::AggCol, ic.col_cycles);
                cycles.add(OpCategory::AggRow, ic.row_cycles);
            }
            OpCategory::ColTransform => {
                cycles.add(OpCategory::ColTransform, ic.total_cycles())
            }
            cat => cycles.add(*cat, ic.total_cycles()),
        }
    }
    cycles
}

pub(crate) fn simulate(
    cfg: &SystemConfig,
    _q: &Query,
    compiled: &[CompiledRelQuery],
    layout: &DbLayout,
) -> QueryMetrics {
    let mut sched = MediaScheduler::new(cfg);
    let mut power = PowerTrace::new(cfg.pim_modules);
    let mut energy = EnergyLedger::default();
    let xbars_per_page = cfg.xbars_per_page();
    let ctrls_per_page = cfg.pim_ctrls_per_page();

    // per-step costs, shared across threads/pages
    let costs: Vec<Vec<_>> = compiled
        .iter()
        .map(|c| {
            c.steps
                .iter()
                .map(|s| (cost(&s.instr, cfg.xbar_rows), s.category))
                .collect()
        })
        .collect();

    // Table 5 cycle counts: per-program, combined commutatively.
    let mut cycles = CycleCounts::default();
    for cs in &costs {
        cycles.merge(&count_cycles(cs));
    }

    let threads = cfg.exec_threads.max(1);
    let spawn_ps =
        (host::core::spawn_join_overhead_s(cfg, threads) * 1e12) as u64;
    let mut pim_ps = 0u64;
    let mut read_ps = 0u64;
    let mut total_read_bytes = 0u64;
    let mut host_combine_instr = 0u64;

    let logic_pj_col = cfg.logic_energy_fj_per_bit * 1e-3 * cfg.xbar_rows as f64;
    let logic_pj_row = cfg.logic_energy_fj_per_bit * 1e-3;

    // All worker threads execute the same phase structure on disjoint page
    // sets and synchronize at fences (paper §5.4), so the simulation runs
    // the phases in lockstep: within a phase, all threads' requests are
    // issued interleaved (`threads` concurrent issue streams); the fence
    // waits for the slowest page.
    let mut cursor = spawn_ps;
    for (c, cs) in compiled.iter().zip(&costs) {
        let rl = layout.rel(c.rel);
        let pages = &rl.pages;
        let issue_gap = (ISSUE_GAP_PS / threads as u64).max(1);

        // computation phase: every instruction to every page
        let mut phase_end = cursor;
        let mut issue = cursor;
        for (ic, _cat) in cs {
            for page in pages {
                let req = Request {
                    loc: page.loc,
                    kind: ReqKind::Pim {
                        cycles: ic.total_cycles(),
                    },
                    issue_ps: issue,
                };
                let done = sched.schedule(&req);
                issue += issue_gap;
                phase_end = phase_end.max(done.end_ps);
                // energy: column ops switch a cell per row per crossbar,
                // row ops one cell per crossbar
                let e_pj = ic.col_cycles as f64 * logic_pj_col * xbars_per_page as f64
                    + ic.row_cycles as f64 * logic_pj_row * xbars_per_page as f64;
                energy.logic_pj += e_pj;
                let (b0, b1) = done.pim_busy;
                energy.add_ctrl_time(cfg, ctrls_per_page, b1.saturating_sub(b0));
                power.deposit(page.loc.module, b0, b1, e_pj);
            }
        }
        pim_ps += phase_end.saturating_sub(cursor);
        cursor = phase_end; // fence

        // read phase: stream results from every page. Besides channel and
        // bank occupancy, the host issues the reads as demand cache-line
        // loads, so each thread sustains at most `host_mlp` outstanding
        // lines — this is what keeps read-out dominant in the paper's
        // Fig. 9: PIM reduces *what* is read, not the per-line latency.
        let mut issue = cursor;
        let mut read_end = cursor;
        let mut rel_read_bytes = 0u64;
        for (pi, page) in pages.iter().enumerate() {
            let bytes = page_read_bytes(c, rl, cfg, pi as u64);
            if bytes == 0 {
                continue;
            }
            let req = Request {
                loc: page.loc,
                kind: ReqKind::ReadBurst { bytes },
                issue_ps: issue,
            };
            let done = sched.schedule(&req);
            issue += issue_gap;
            read_end = read_end.max(done.end_ps);
            rel_read_bytes += bytes;
            total_read_bytes += bytes;
            energy.add_read_bits(cfg, bytes * 8);
            energy.add_io_bytes(cfg, bytes);
            power.deposit(
                page.loc.module,
                done.start_ps,
                done.end_ps,
                bytes as f64 * 8.0 * cfg.read_energy_pj_per_bit,
            );
        }
        // host-MLP-limited demand reads, split across threads; a relation
        // on a single page cannot be split further (Q11's case)
        let read_threads = pages.len().min(threads).max(1) as u64;
        let lines = rel_read_bytes.div_ceil(cfg.cache_block as u64) / read_threads;
        let line_latency_ps = (cfg.opencapi_latency_ns + cfg.rram_read_ns) * 1000;
        let host_limited =
            cursor + (lines as f64 * line_latency_ps as f64 / cfg.host_mlp) as u64;
        read_end = read_end.max(host_limited);
        read_ps += read_end.saturating_sub(cursor);
        cursor = read_end; // fence

        // host-side combine work for aggregates (2 ops per value read)
        if let ReadKind::Aggregates { values, .. } = c.read {
            let xbars = rl.records_report.div_ceil(cfg.xbar_rows as u64);
            host_combine_instr += 2 * values as u64 * xbars / threads as u64;
        } else {
            // scanning the filter bitmap words
            host_combine_instr += rl.records_report / 64 / threads as u64;
        }
    }

    let mem_time_s = cursor as f64 * 1e-12;
    let combine_act = host::core::Activity {
        instructions: host_combine_instr,
        ..Default::default()
    };
    let other_s = host::core::thread_time_s(cfg, &combine_act, 1.0)
        + host::core::spawn_join_overhead_s(cfg, threads);
    let exec_time_s = mem_time_s + host::core::thread_time_s(cfg, &combine_act, 1.0);

    // endurance: per-relation trackers; the binding constraint is the
    // hottest row over any relation the query touches
    let mut worst_ops_per_cell = 0.0f64;
    let mut worst_breakdown = [0.0; 5];
    for c in compiled {
        let mut tr = EnduranceTracker::new(cfg.xbar_rows, cfg.xbar_cols);
        record_endurance(&mut tr, &c.steps, cfg.xbar_rows);
        let opc = tr.max_ops_per_cell();
        if opc > worst_ops_per_cell {
            worst_ops_per_cell = opc;
            worst_breakdown = tr.breakdown_fractions();
        }
    }

    // theoretical peak power: pages of this query in the busiest module
    let mut pages_per_module = vec![0u64; cfg.pim_modules];
    for c in compiled {
        for p in &layout.rel(c.rel).pages {
            pages_per_module[p.loc.module] += 1;
        }
    }
    let max_pages = pages_per_module.iter().copied().max().unwrap_or(0);

    let dram = crate::mem::dram::DramModel::new(cfg);
    let executions_per_10yr = 10.0 * 365.25 * 24.0 * 3600.0 / exec_time_s.max(1e-12);

    // finalize the power trace once (it sorts the rate marks)
    let fin = power.finalize();
    let chips = cfg.chips_per_module as f64;
    let peak_chip_w = fin.iter().fold(0.0f64, |a, &(p, _)| a.max(p)) / chips;
    let avg_chip_w = fin.iter().fold(0.0f64, |a, &(_, v)| a.max(v)) / chips;

    QueryMetrics {
        exec_time_s,
        pim_time_s: pim_ps as f64 * 1e-12,
        read_time_s: read_ps as f64 * 1e-12,
        other_time_s: other_s,
        llc_misses: total_read_bytes / cfg.cache_block as u64,
        host_energy_pj: host::power::host_energy_pj(cfg, exec_time_s, other_s, cfg.exec_threads),
        dram_energy_pj: dram.standby_energy_pj(exec_time_s),
        pim_energy: energy,
        cycles,
        inter_cells: 0, // filled by caller
        opt: OptSummary::default(), // filled by caller
        plan_cache: Default::default(), // filled by the api facade
        shards_skipped: 0,      // filled by the api facade
        steps_short_circuited: 0, // filled by the api facade
        peak_chip_w,
        avg_chip_w,
        theoretical_chip_w: power::theoretical_peak_query_chip_w(cfg, max_pages),
        ops_per_cell: worst_ops_per_cell,
        required_endurance_10yr: worst_ops_per_cell * executions_per_10yr,
        endurance_breakdown: worst_breakdown,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::tpch;

    fn db() -> Database {
        Database::generate(0.001, 11)
    }

    #[test]
    fn q6_runs_native_end_to_end() {
        let cfg = SystemConfig::default();
        let q = tpch::query("Q6").unwrap();
        let r = run_query(&cfg, &db(), &q, EngineKind::Native).unwrap();
        assert!(r.metrics.exec_time_s > 0.0);
        assert!(r.metrics.pim_time_s > 0.0);
        assert!(r.metrics.read_time_s > 0.0);
        assert_eq!(r.output.groups.len(), 1);
        assert!(r.metrics.cycles.agg_col > 0 && r.metrics.cycles.agg_row > 0);
    }

    #[test]
    fn q6_aggregate_matches_scalar_oracle() {
        let cfg = SystemConfig::default();
        let database = db();
        let q = tpch::query("Q6").unwrap();
        let r = run_query(&cfg, &database, &q, EngineKind::Native).unwrap();
        // scalar oracle
        let li = database.rel(crate::db::schema::RelId::Lineitem);
        let rq = &q.rels[0];
        let mut want: u128 = 0;
        let mut count = 0u64;
        for i in 0..li.records {
            let get = |n: &str| li.col(n)[i];
            if rq.filter.eval(&get) {
                want += rq.aggregates[0].expr.eval(&get);
                count += 1;
            }
        }
        let got = r.output.groups[0].values[0].1;
        assert_eq!(got as u128, want, "sum mismatch");
        assert_eq!(r.output.selected[0].1, count);
    }

    #[test]
    fn filter_only_query_reports_selected() {
        let cfg = SystemConfig::default();
        let database = db();
        let q = tpch::query("Q12").unwrap();
        let r = run_query(&cfg, &database, &q, EngineKind::Native).unwrap();
        let li = database.rel(crate::db::schema::RelId::Lineitem);
        let rq = &q.rels[0];
        let want = (0..li.records)
            .filter(|&i| rq.filter.eval(&|n| li.col(n)[i]))
            .count() as u64;
        assert_eq!(r.output.selected[0].1, want);
        assert!(r.metrics.cycles.col_transform > 0);
        assert_eq!(r.metrics.cycles.agg_col, 0);
    }

    #[test]
    fn q1_groups_match_oracle() {
        let cfg = SystemConfig::default();
        let database = db();
        let q = tpch::query("Q1").unwrap();
        let r = run_query(&cfg, &database, &q, EngineKind::Native).unwrap();
        let li = database.rel(crate::db::schema::RelId::Lineitem);
        let rq = &q.rels[0];
        // oracle per (returnflag, linestatus)
        use std::collections::BTreeMap;
        let mut oracle: BTreeMap<(u64, u64), (u128, u64)> = BTreeMap::new();
        for i in 0..li.records {
            let get = |n: &str| li.col(n)[i];
            if rq.filter.eval(&get) {
                let k = (get("l_returnflag"), get("l_linestatus"));
                let e = oracle.entry(k).or_default();
                e.0 += rq.aggregates[0].expr.eval(&get); // sum_qty
                e.1 += 1;
            }
        }
        for g in &r.output.groups {
            let k = (g.key[0].1, g.key[1].1);
            if let Some(&(sum_qty, cnt)) = oracle.get(&k) {
                assert_eq!(g.values[0].1 as u128, sum_qty, "group {:?}", k);
                assert_eq!(g.count, cnt);
            } else {
                assert_eq!(g.count, 0);
            }
        }
        // every nonempty oracle group appears
        let nonempty = oracle.len();
        assert_eq!(
            r.output.groups.iter().filter(|g| g.count > 0).count(),
            nonempty
        );
    }

    #[test]
    fn q22_avg_host_division() {
        let cfg = SystemConfig::default();
        let database = db();
        let q = tpch::query("Q22_sub").unwrap();
        let r = run_query(&cfg, &database, &q, EngineKind::Native).unwrap();
        let cu = database.rel(crate::db::schema::RelId::Customer);
        let rq = &q.rels[0];
        let mut sum = 0u128;
        let mut n = 0u64;
        for i in 0..cu.records {
            let get = |nm: &str| cu.col(nm)[i];
            if rq.filter.eval(&get) {
                sum += get("c_acctbal") as u128;
                n += 1;
            }
        }
        let want = sum as f64 / n as f64;
        let got = r.output.groups[0].values[0].1;
        assert!((got - want).abs() < 1e-6, "avg {got} vs {want}");
    }

    #[test]
    fn full_query_reads_less_than_filter_only_per_record() {
        // aggregation reads one value per crossbar vs one bit per record
        let cfg = SystemConfig::default();
        let database = db();
        let q6 = run_query(&cfg, &database, &tpch::query("Q6").unwrap(), EngineKind::Native).unwrap();
        let q14 =
            run_query(&cfg, &database, &tpch::query("Q14").unwrap(), EngineKind::Native).unwrap();
        // same relation; Q6 reads aggregates only -> fewer LLC misses
        assert!(q6.metrics.llc_misses < q14.metrics.llc_misses);
    }

    #[test]
    fn parallel_session_matches_serial_session() {
        let cfg_serial = SystemConfig {
            parallelism: 1,
            ..SystemConfig::default()
        };
        let cfg_par = SystemConfig {
            parallelism: 3,
            ..SystemConfig::default()
        };
        let database = db();
        let mut s_serial = PimSession::new(&cfg_serial, &database).unwrap();
        let mut s_par = PimSession::new(&cfg_par, &database).unwrap();
        for name in ["Q6", "Q1", "Q12"] {
            let q = tpch::query(name).unwrap();
            let a = s_serial.run_query(&q, EngineKind::Native).unwrap();
            let b = s_par.run_query(&q, EngineKind::Native).unwrap();
            assert_eq!(a.output, b.output, "{name}");
            assert_eq!(a.metrics.cycles, b.metrics.cycles, "{name}");
            assert_eq!(
                a.metrics.exec_time_s.to_bits(),
                b.metrics.exec_time_s.to_bits(),
                "{name}"
            );
        }
    }

    #[test]
    fn run_queries_batch_matches_individual() {
        let cfg = SystemConfig {
            parallelism: 4,
            ..SystemConfig::default()
        };
        let database = db();
        let queries: Vec<_> = ["Q6", "Q11", "Q22_sub", "Q6", "Q12"]
            .iter()
            .map(|n| tpch::query(n).unwrap())
            .collect();
        let mut batch = PimSession::new(&cfg, &database).unwrap();
        let reports = batch.run_queries(&queries, EngineKind::Native).unwrap();
        assert_eq!(reports.len(), queries.len());
        let mut single = PimSession::new(&cfg, &database).unwrap();
        for (q, r) in queries.iter().zip(&reports) {
            let want = single.run_query(q, EngineKind::Native).unwrap();
            assert_eq!(want.output, r.output, "{}", q.name);
            assert_eq!(want.metrics.cycles, r.metrics.cycles, "{}", q.name);
        }
    }

    #[test]
    fn opt_levels_agree_functionally_and_o2_saves_cycles() {
        use crate::query::opt::OptLevel;
        let database = db();
        let cfg_o0 = SystemConfig {
            opt_level: OptLevel::O0,
            ..SystemConfig::default()
        };
        let cfg_o2 = SystemConfig::default(); // -O2 default
        let mut s0 = PimSession::new(&cfg_o0, &database).unwrap();
        let mut s2 = PimSession::new(&cfg_o2, &database).unwrap();
        for name in ["Q1", "Q6", "Q12", "Q22_sub"] {
            let q = tpch::query(name).unwrap();
            let a = s0.run_query(&q, EngineKind::Native).unwrap();
            let b = s2.run_query(&q, EngineKind::Native).unwrap();
            assert_eq!(a.output, b.output, "{name}");
            assert!(
                b.metrics.cycles.total() <= a.metrics.cycles.total(),
                "{name}"
            );
            assert!(b.metrics.inter_cells <= a.metrics.inter_cells, "{name}");
            // the summary records the delta
            assert_eq!(b.metrics.opt.cycles_before, a.metrics.cycles.total());
            assert_eq!(b.metrics.opt.cycles_after, b.metrics.cycles.total());
            assert_eq!(a.metrics.opt.cycles_before, a.metrics.opt.cycles_after);
        }
    }

    #[test]
    fn session_dml_mutates_the_pim_copy() {
        use crate::db::schema::RelId;
        use crate::query::lang::{parse_dml, parse_program};
        let cfg = SystemConfig::default();
        let database = db();
        let before = database.rel(RelId::Supplier).records;
        let mut s = PimSession::new(&cfg, &database).unwrap();

        let del = parse_dml("delete from supplier where s_suppkey <= 4").unwrap();
        let r = s.run_dml(&del, EngineKind::Native).unwrap();
        assert_eq!(r.rows_affected, 4);
        assert!(r.wear_delta > 0.0);
        assert!(r.metrics.exec_time_s > 0.0);
        assert!(r.metrics.cycles.filter > 0, "filter cycles charged");
        assert_eq!(s.live_records(RelId::Supplier), before - 4);

        let ins = parse_dml("insert into supplier (s_suppkey) values (777)").unwrap();
        let r = s.run_dml(&ins, EngineKind::Native).unwrap();
        assert_eq!(r.rows_affected, 1);
        assert!(r.metrics.pim_time_s > 0.0, "array write time charged");
        assert!(r.metrics.llc_misses > 0, "flush accounting present");
        assert_eq!(s.live_records(RelId::Supplier), before - 3);

        // the query path sees the mutated copy
        let q = parse_program(
            "from supplier | filter true | aggregate count() as n",
        )
        .unwrap();
        let rep = s.run_query(&q[0], EngineKind::Native).unwrap();
        assert_eq!(rep.output.groups[0].count as usize, before - 3);
        // dml on an unknown attribute is a typed compile error
        let bad = crate::query::ast::Dml::Update {
            rel: RelId::Supplier,
            filter: crate::query::ast::Pred::True,
            sets: vec![("nope", 1)],
        };
        assert!(matches!(
            s.run_dml(&bad, EngineKind::Native),
            Err(PimdbError::Compile(CompileError::NoSuchAttribute { .. }))
        ));
    }

    #[test]
    fn run_queries_empty_batch_is_ok() {
        let cfg = SystemConfig::default();
        let database = db();
        let mut s = PimSession::new(&cfg, &database).unwrap();
        assert!(s
            .run_queries(&[], EngineKind::Native)
            .unwrap()
            .is_empty());
    }
}

#[cfg(test)]
mod pjrt_tests {
    use super::*;
    use crate::query::tpch;

    /// End-to-end Q6 through the PJRT engine must equal the native engine.
    /// Skips when the artifacts/PJRT runtime are unavailable.
    #[test]
    fn q6_pjrt_equals_native() {
        if !crate::runtime::runtime_available() {
            eprintln!("skipping: PJRT runtime/artifacts unavailable");
            return;
        }
        let cfg = SystemConfig::default();
        let database = Database::generate(0.001, 11);
        let q = tpch::query("Q6").unwrap();
        let a = run_query(&cfg, &database, &q, EngineKind::Native).unwrap();
        let b = run_query(&cfg, &database, &q, EngineKind::Pjrt).unwrap();
        assert_eq!(a.output, b.output);
    }
}
