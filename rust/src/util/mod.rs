//! Small self-contained utilities: bit vectors, PRNG, statistics, and a
//! mini property-testing harness (the offline vendor set has no `proptest`).

pub mod bits;
pub mod proptest;
pub mod rng;
pub mod stats;

pub use bits::BitMatrix;
pub use rng::Rng;
