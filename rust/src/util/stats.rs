//! Lightweight statistics helpers for the simulators and the bench harness.

/// Online mean/min/max/sum accumulator.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Samples seen.
    pub n: u64,
    /// Sum of samples.
    pub sum: f64,
    /// Smallest sample (+inf when empty).
    pub min: f64,
    /// Largest sample (-inf when empty).
    pub max: f64,
}

impl Summary {
    /// An empty accumulator.
    pub fn new() -> Self {
        Summary {
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold in one sample.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

/// Fixed-window peak/average power sampler (paper §6.3: 100 ns windows).
/// Energy deposits are attributed to windows by timestamp; `peak()` returns
/// the maximum window energy / window length.
#[derive(Clone, Debug)]
pub struct WindowedPower {
    window_ps: u64,
    windows: Vec<f64>, // energy in pJ per window
    total_pj: f64,
    end_ps: u64,
}

impl WindowedPower {
    /// An empty sampler with `window_ps`-long windows.
    pub fn new(window_ps: u64) -> Self {
        WindowedPower {
            window_ps,
            windows: Vec::new(),
            total_pj: 0.0,
            end_ps: 0,
        }
    }

    /// Deposit `energy_pj` uniformly over [start_ps, start_ps + dur_ps).
    pub fn deposit(&mut self, start_ps: u64, dur_ps: u64, energy_pj: f64) {
        let dur = dur_ps.max(1);
        let first = (start_ps / self.window_ps) as usize;
        let last = ((start_ps + dur - 1) / self.window_ps) as usize;
        if self.windows.len() <= last {
            self.windows.resize(last + 1, 0.0);
        }
        let per_ps = energy_pj / dur as f64;
        for w in first..=last {
            let ws = (w as u64) * self.window_ps;
            let we = ws + self.window_ps;
            let ov = (start_ps + dur).min(we).saturating_sub(start_ps.max(ws));
            self.windows[w] += per_ps * ov as f64;
        }
        self.total_pj += energy_pj;
        self.end_ps = self.end_ps.max(start_ps + dur);
    }

    /// Peak power in watts (pJ / ps == W).
    pub fn peak_w(&self) -> f64 {
        self.windows
            .iter()
            .fold(0.0f64, |a, &e| a.max(e / self.window_ps as f64))
    }

    /// Average power over the observed span, in watts.
    pub fn avg_w(&self) -> f64 {
        if self.end_ps == 0 {
            0.0
        } else {
            self.total_pj / self.end_ps as f64
        }
    }

    /// Total deposited energy (pJ).
    pub fn total_pj(&self) -> f64 {
        self.total_pj
    }
}

/// Pretty-print a float with engineering suffix.
pub fn eng(x: f64) -> String {
    let ax = x.abs();
    let (val, suf) = if ax >= 1e12 {
        (x / 1e12, "T")
    } else if ax >= 1e9 {
        (x / 1e9, "G")
    } else if ax >= 1e6 {
        (x / 1e6, "M")
    } else if ax >= 1e3 {
        (x / 1e3, "k")
    } else if ax >= 1.0 || x == 0.0 {
        (x, "")
    } else if ax >= 1e-3 {
        (x * 1e3, "m")
    } else if ax >= 1e-6 {
        (x * 1e6, "u")
    } else if ax >= 1e-9 {
        (x * 1e9, "n")
    } else {
        (x * 1e12, "p")
    };
    format!("{val:.3}{suf}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_tracks_extrema() {
        let mut s = Summary::new();
        for x in [3.0, -1.0, 7.0] {
            s.add(x);
        }
        assert_eq!(s.min, -1.0);
        assert_eq!(s.max, 7.0);
        assert!((s.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn windowed_power_peak_and_avg() {
        let mut w = WindowedPower::new(100_000); // 100 ns in ps
        // 1 W for one full window: 100_000 ps * 1 pJ/ps = 1e5 pJ
        w.deposit(0, 100_000, 1e5);
        // 0.5 W for the next window
        w.deposit(100_000, 100_000, 5e4);
        assert!((w.peak_w() - 1.0).abs() < 1e-9);
        assert!((w.avg_w() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn windowed_power_split_across_windows() {
        let mut w = WindowedPower::new(100);
        w.deposit(50, 100, 200.0); // spans two windows, half each
        assert!((w.windows[0] - 100.0).abs() < 1e-9);
        assert!((w.windows[1] - 100.0).abs() < 1e-9);
    }

    #[test]
    fn eng_format() {
        assert_eq!(eng(1.5e9), "1.500G");
        assert_eq!(eng(0.002), "2.000m");
    }
}
