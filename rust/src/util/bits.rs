//! Packed bit containers used across the functional PIM engine.
//!
//! The crossbar row axis (1024 rows) packs into `WORDS = 16` u64 words —
//! one cache line per bit-plane, sized so the fixed-width inner loops in
//! `exec::engine` autovectorize. The L1 Pallas kernels keep their own
//! `u32[KERNEL_WORDS]` plane layout (DESIGN.md §Hardware-Adaptation);
//! the PJRT boundary in `runtime::exec` splits each u64 into lo/hi u32
//! halves on gather and recombines on scatter, so the kernel ABI is
//! unchanged by the host-side word width.

/// Crossbar rows (paper Table 3).
pub const XBAR_ROWS: usize = 1024;
/// Crossbar columns (paper Table 3).
pub const XBAR_COLS: usize = 512;
/// Bits per packed plane word (host-side kernel word width).
pub const WORD_BITS: usize = 64;
/// u64 words per bit-plane column.
pub const WORDS: usize = XBAR_ROWS / WORD_BITS;
/// u32 words per bit-plane column in the L1 Pallas kernel ABI (the PJRT
/// literals keep the original u32 packing; see `runtime::exec`).
pub const KERNEL_WORDS: usize = XBAR_ROWS / 32;
/// Bit-planes carried by the generic ALU executables.
pub const PLANES: usize = 64;
/// Crossbars per exported executable invocation (must match python XB_TILE).
pub const XB_TILE: usize = 16;
/// Bits retrieved by one crossbar read (paper Table 3).
pub const XBAR_READ_BITS: usize = 16;

// --- explicit SIMD lanes -----------------------------------------------------
//
// Portable 4-wide u64 vectors for the hot bit-plane kernels. A plane's 16
// words are processed as 4 chunks of 4 lanes; each lane primitive is a
// branch-free fixed-width array expression, which every release build
// lowers to one 256-bit vector op (or two 128-bit ops) without nightly
// `std::simd`. The engine's And/Or/Not/Xor, compare and popcount-reduce
// loops are written against these primitives rather than scalar
// word-at-a-time loops; `RowMask` uses the same primitives so host-side
// mask algebra and the engine kernels share one code shape.

/// Lanes per SIMD chunk (u64x4: one 256-bit vector register).
pub const LANES: usize = 4;
/// SIMD chunks per bit-plane (`WORDS / LANES`).
pub const WORD_CHUNKS: usize = WORDS / LANES;
const _: () = assert!(WORDS % LANES == 0, "plane words must chunk evenly into SIMD lanes");

/// A portable 4-lane u64 vector.
pub type U64x4 = [u64; LANES];

/// Load chunk `c` (lanes `4c..4c+4`) of a packed plane.
#[inline]
pub fn load_lanes(p: &[u64; WORDS], c: usize) -> U64x4 {
    let i = c * LANES;
    [p[i], p[i + 1], p[i + 2], p[i + 3]]
}

/// Store chunk `c` of a packed plane.
#[inline]
pub fn store_lanes(p: &mut [u64; WORDS], c: usize, v: U64x4) {
    p[c * LANES..(c + 1) * LANES].copy_from_slice(&v);
}

/// Lane-wise AND.
#[inline]
pub fn vand(a: U64x4, b: U64x4) -> U64x4 {
    [a[0] & b[0], a[1] & b[1], a[2] & b[2], a[3] & b[3]]
}

/// Lane-wise OR.
#[inline]
pub fn vor(a: U64x4, b: U64x4) -> U64x4 {
    [a[0] | b[0], a[1] | b[1], a[2] | b[2], a[3] | b[3]]
}

/// Lane-wise XOR.
#[inline]
pub fn vxor(a: U64x4, b: U64x4) -> U64x4 {
    [a[0] ^ b[0], a[1] ^ b[1], a[2] ^ b[2], a[3] ^ b[3]]
}

/// Lane-wise complement.
#[inline]
pub fn vnot(a: U64x4) -> U64x4 {
    [!a[0], !a[1], !a[2], !a[3]]
}

/// Horizontal popcount of all four lanes.
#[inline]
pub fn vpopcount(a: U64x4) -> u64 {
    (a[0].count_ones() + a[1].count_ones() + a[2].count_ones() + a[3].count_ones()) as u64
}

/// Number of set bits in a packed plane, accumulated chunk-at-a-time.
#[inline]
pub fn popcount_words(p: &[u64; WORDS]) -> u64 {
    let mut n = 0u64;
    for c in 0..WORD_CHUNKS {
        n += vpopcount(load_lanes(p, c));
    }
    n
}

/// Whether a packed plane is all-zero, folded lane-wise: one running
/// [`U64x4`] OR accumulator over the chunks, then a horizontal check —
/// cheaper than a full popcount on the runtime short-circuit path.
#[inline]
pub fn is_zero_words(p: &[u64; WORDS]) -> bool {
    let mut acc = load_lanes(p, 0);
    for c in 1..WORD_CHUNKS {
        acc = vor(acc, load_lanes(p, c));
    }
    (acc[0] | acc[1] | acc[2] | acc[3]) == 0
}

/// A dense 2-D bit matrix, `rows x cols`, row-major, bit-addressable.
/// Used by the cell-accurate crossbar reference model.
#[derive(Clone, PartialEq, Eq)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    data: Vec<u64>,
}

impl BitMatrix {
    /// An all-zero matrix of the given shape.
    pub fn new(rows: usize, cols: usize) -> Self {
        let words_per_row = cols.div_ceil(64);
        BitMatrix {
            rows,
            cols,
            words_per_row,
            data: vec![0; rows * words_per_row],
        }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Bit at (r, c).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        let w = self.data[r * self.words_per_row + c / 64];
        (w >> (c % 64)) & 1 == 1
    }

    /// Set bit (r, c) to `v`.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        let w = &mut self.data[r * self.words_per_row + c / 64];
        if v {
            *w |= 1 << (c % 64);
        } else {
            *w &= !(1 << (c % 64));
        }
    }

    /// Read `n <= 64` bits of row `r` starting at column `c` (LSB-first).
    pub fn read_bits(&self, r: usize, c: usize, n: usize) -> u64 {
        debug_assert!(n <= 64 && c + n <= self.cols);
        let mut v = 0u64;
        for i in 0..n {
            if self.get(r, c + i) {
                v |= 1 << i;
            }
        }
        v
    }

    /// Write `n <= 64` bits of row `r` starting at column `c` (LSB-first).
    pub fn write_bits(&mut self, r: usize, c: usize, n: usize, v: u64) {
        debug_assert!(n <= 64 && c + n <= self.cols);
        for i in 0..n {
            self.set(r, c + i, (v >> i) & 1 == 1);
        }
    }
}

impl std::fmt::Debug for BitMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BitMatrix({}x{})", self.rows, self.cols)
    }
}

/// One bit per crossbar row, packed: a crossbar *column* (e.g. a filter
/// result mask). Same `u64[WORDS]` packing as the engine's bit-planes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RowMask(pub [u64; WORDS]);

impl Default for RowMask {
    fn default() -> Self {
        RowMask([0; WORDS])
    }
}

impl RowMask {
    /// Every row selected.
    pub fn all_ones() -> Self {
        RowMask([u64::MAX; WORDS])
    }

    /// Only the first `n` rows set.
    pub fn first_n(n: usize) -> Self {
        let mut m = RowMask::default();
        for r in 0..n.min(XBAR_ROWS) {
            m.set(r, true);
        }
        m
    }

    /// Whether `row` is selected.
    #[inline]
    pub fn get(&self, row: usize) -> bool {
        debug_assert!(row < XBAR_ROWS, "RowMask::get row {row} out of range");
        (self.0[row / WORD_BITS] >> (row % WORD_BITS)) & 1 == 1
    }

    /// Select or clear `row`.
    #[inline]
    pub fn set(&mut self, row: usize, v: bool) {
        debug_assert!(row < XBAR_ROWS, "RowMask::set row {row} out of range");
        if v {
            self.0[row / WORD_BITS] |= 1 << (row % WORD_BITS);
        } else {
            self.0[row / WORD_BITS] &= !(1 << (row % WORD_BITS));
        }
    }

    /// Number of selected rows.
    pub fn count_ones(&self) -> u32 {
        popcount_words(&self.0) as u32
    }

    /// Row-wise AND.
    pub fn and(&self, o: &RowMask) -> RowMask {
        let mut r = [0u64; WORDS];
        for c in 0..WORD_CHUNKS {
            store_lanes(&mut r, c, vand(load_lanes(&self.0, c), load_lanes(&o.0, c)));
        }
        RowMask(r)
    }

    /// Row-wise OR.
    pub fn or(&self, o: &RowMask) -> RowMask {
        let mut r = [0u64; WORDS];
        for c in 0..WORD_CHUNKS {
            store_lanes(&mut r, c, vor(load_lanes(&self.0, c), load_lanes(&o.0, c)));
        }
        RowMask(r)
    }

    /// Row-wise complement.
    pub fn not(&self) -> RowMask {
        let mut r = [0u64; WORDS];
        for c in 0..WORD_CHUNKS {
            store_lanes(&mut r, c, vnot(load_lanes(&self.0, c)));
        }
        RowMask(r)
    }

    /// Indices of the selected rows, ascending.
    pub fn iter_rows(&self) -> impl Iterator<Item = usize> + '_ {
        (0..XBAR_ROWS).filter(move |&r| self.get(r))
    }
}

/// Bit-plane set of one attribute over one crossbar: `planes[i][w]` holds
/// bit `i` of rows `64w..64w+64`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlaneSet {
    /// Number of bit-planes (attribute width).
    pub nplanes: usize,
    /// The packed planes, LSB first.
    pub planes: Vec<[u64; WORDS]>,
}

impl PlaneSet {
    /// An all-zero plane set `nplanes` wide.
    pub fn zero(nplanes: usize) -> Self {
        PlaneSet {
            nplanes,
            planes: vec![[0; WORDS]; nplanes],
        }
    }

    /// Pack per-row values (LSB-first planes).
    pub fn pack(values: &[u64], nplanes: usize) -> Self {
        debug_assert!(values.len() <= XBAR_ROWS);
        let mut ps = PlaneSet::zero(nplanes);
        for (r, &v) in values.iter().enumerate() {
            for i in 0..nplanes {
                if (v >> i) & 1 == 1 {
                    ps.planes[i][r / WORD_BITS] |= 1 << (r % WORD_BITS);
                }
            }
        }
        ps
    }

    /// Unpack back to per-row values.
    pub fn unpack(&self) -> Vec<u64> {
        let mut vals = vec![0u64; XBAR_ROWS];
        for i in 0..self.nplanes {
            for w in 0..WORDS {
                let mut bits = self.planes[i][w];
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    vals[w * WORD_BITS + b] |= 1 << i;
                    bits &= bits - 1;
                }
            }
        }
        vals
    }

    /// The integer value stored in `row`.
    pub fn value_at(&self, row: usize) -> u64 {
        let mut v = 0u64;
        for i in 0..self.nplanes {
            if (self.planes[i][row / WORD_BITS] >> (row % WORD_BITS)) & 1 == 1 {
                v |= 1 << i;
            }
        }
        v
    }
}

/// Two-plane per-row visibility mask — the epoch scheme behind snapshot
/// reads under concurrent DML.
///
/// One plane is *active* (what committed readers see); the other is the
/// *shadow* a DML batch edits. [`EpochMask::begin_batch`] copies the
/// active plane into the shadow, the batch mutates the shadow via
/// [`EpochMask::set_pending`], and [`EpochMask::commit_batch`] flips
/// which plane is active — a single index store, so visibility changes
/// atomically for everyone who reads the mask *after* the flip while
/// snapshots taken before it keep their own copy of the old plane.
/// [`EpochMask::abort_batch`] simply discards the shadow.
///
/// Bits are flat row indices over the whole relation (not one crossbar),
/// packed LSB-first into `u64` words like every other mask in the engine.
/// The all-zero-dead-row invariant (DELETE zeroes a victim's data
/// columns) is what makes this second liveness plane sufficient for
/// MVCC: a row dead in a snapshot's plane contributes all-zero planes,
/// so the optimizer's valid-AND elision stays sound per epoch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EpochMask {
    nbits: usize,
    active: usize,
    in_batch: bool,
    planes: [Vec<u64>; 2],
}

impl EpochMask {
    /// An all-dead mask over `nbits` rows.
    pub fn new(nbits: usize) -> Self {
        let words = nbits.div_ceil(WORD_BITS);
        EpochMask {
            nbits,
            active: 0,
            in_batch: false,
            planes: [vec![0; words], vec![0; words]],
        }
    }

    /// A mask whose active plane is `flags` (shadow starts all-zero).
    /// Rows beyond `flags.len()` up to `nbits` are dead.
    pub fn from_flags(flags: &[bool], nbits: usize) -> Self {
        assert!(flags.len() <= nbits, "more flags than rows");
        let mut m = EpochMask::new(nbits);
        for (i, &f) in flags.iter().enumerate() {
            if f {
                m.planes[0][i / WORD_BITS] |= 1 << (i % WORD_BITS);
            }
        }
        m
    }

    /// Rows tracked.
    pub fn capacity(&self) -> usize {
        self.nbits
    }

    /// Whether a shadow plane is currently being edited.
    pub fn in_batch(&self) -> bool {
        self.in_batch
    }

    /// Visibility of `row` in the *active* (committed) plane.
    #[inline]
    pub fn get(&self, row: usize) -> bool {
        debug_assert!(row < self.nbits, "EpochMask::get row {row} out of range");
        (self.planes[self.active][row / WORD_BITS] >> (row % WORD_BITS)) & 1 == 1
    }

    /// Live rows in the active plane.
    pub fn count_ones(&self) -> usize {
        let full = self.nbits / WORD_BITS;
        let mut n: usize = self.planes[self.active][..full]
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum();
        if self.nbits % WORD_BITS != 0 {
            let tail = self.planes[self.active][full] & ((1u64 << (self.nbits % WORD_BITS)) - 1);
            n += tail.count_ones() as usize;
        }
        n
    }

    /// Start a batch: copy the active plane into the shadow so the batch
    /// edits a consistent starting point. Panics on a nested batch.
    pub fn begin_batch(&mut self) {
        assert!(!self.in_batch, "nested EpochMask batch");
        self.planes[1 - self.active] = self.planes[self.active].clone();
        self.in_batch = true;
    }

    /// Set `row`'s visibility in the shadow plane (batch only).
    #[inline]
    pub fn set_pending(&mut self, row: usize, v: bool) {
        debug_assert!(self.in_batch, "set_pending outside a batch");
        debug_assert!(row < self.nbits, "EpochMask::set_pending row {row} out of range");
        let w = &mut self.planes[1 - self.active][row / WORD_BITS];
        if v {
            *w |= 1 << (row % WORD_BITS);
        } else {
            *w &= !(1 << (row % WORD_BITS));
        }
    }

    /// Visibility of `row` in the shadow plane (batch only).
    #[inline]
    pub fn pending(&self, row: usize) -> bool {
        debug_assert!(self.in_batch, "pending outside a batch");
        debug_assert!(row < self.nbits, "EpochMask::pending row {row} out of range");
        (self.planes[1 - self.active][row / WORD_BITS] >> (row % WORD_BITS)) & 1 == 1
    }

    /// Atomically publish the shadow plane: flip which plane is active.
    pub fn commit_batch(&mut self) {
        assert!(self.in_batch, "commit_batch outside a batch");
        self.active = 1 - self.active;
        self.in_batch = false;
    }

    /// Discard the shadow plane; the active plane is untouched.
    pub fn abort_batch(&mut self) {
        assert!(self.in_batch, "abort_batch outside a batch");
        self.in_batch = false;
    }

    /// Append `rows` dead rows to both planes (a newly materialized
    /// crossbar; legal mid-batch — the new rows are dead in both planes).
    pub fn grow(&mut self, rows: usize) {
        self.nbits += rows;
        let words = self.nbits.div_ceil(WORD_BITS);
        self.planes[0].resize(words, 0);
        self.planes[1].resize(words, 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitmatrix_rw_roundtrip() {
        let mut m = BitMatrix::new(16, 100);
        m.write_bits(3, 37, 23, 0x5A5A5A);
        assert_eq!(m.read_bits(3, 37, 23), 0x5A5A5A & ((1 << 23) - 1));
        assert_eq!(m.read_bits(2, 37, 23), 0);
    }

    #[test]
    fn bitmatrix_set_get() {
        let mut m = BitMatrix::new(4, 65);
        m.set(1, 64, true);
        assert!(m.get(1, 64));
        m.set(1, 64, false);
        assert!(!m.get(1, 64));
    }

    #[test]
    fn rowmask_ops() {
        let mut a = RowMask::default();
        a.set(0, true);
        a.set(1023, true);
        assert_eq!(a.count_ones(), 2);
        let b = a.not();
        assert_eq!(b.count_ones(), 1022);
        assert_eq!(a.and(&b).count_ones(), 0);
        assert_eq!(a.or(&b).count_ones(), 1024);
        assert_eq!(a.iter_rows().collect::<Vec<_>>(), vec![0, 1023]);
    }

    #[test]
    fn simd_lanes_match_scalar_word_ops() {
        // deterministic LCG-filled planes exercise every lane position
        let mut a = [0u64; WORDS];
        let mut b = [0u64; WORDS];
        let mut x = 0x9E3779B97F4A7C15u64;
        for i in 0..WORDS {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            a[i] = x;
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            b[i] = x;
        }
        let mut and = [0u64; WORDS];
        let mut or = [0u64; WORDS];
        let mut xor = [0u64; WORDS];
        let mut not = [0u64; WORDS];
        for c in 0..WORD_CHUNKS {
            let (va, vb) = (load_lanes(&a, c), load_lanes(&b, c));
            store_lanes(&mut and, c, vand(va, vb));
            store_lanes(&mut or, c, vor(va, vb));
            store_lanes(&mut xor, c, vxor(va, vb));
            store_lanes(&mut not, c, vnot(va));
        }
        for i in 0..WORDS {
            assert_eq!(and[i], a[i] & b[i], "and word {i}");
            assert_eq!(or[i], a[i] | b[i], "or word {i}");
            assert_eq!(xor[i], a[i] ^ b[i], "xor word {i}");
            assert_eq!(not[i], !a[i], "not word {i}");
        }
        let scalar_pc: u64 = a.iter().map(|w| w.count_ones() as u64).sum();
        assert_eq!(popcount_words(&a), scalar_pc);
    }

    #[test]
    fn is_zero_words_matches_scalar_any() {
        assert!(is_zero_words(&[0u64; WORDS]));
        for w in 0..WORDS {
            for bit in [0usize, 17, 63] {
                let mut p = [0u64; WORDS];
                p[w] = 1u64 << bit;
                assert!(!is_zero_words(&p), "word {w} bit {bit}");
            }
        }
    }

    #[test]
    fn rowmask_first_n() {
        let m = RowMask::first_n(100);
        assert_eq!(m.count_ones(), 100);
        assert!(m.get(99) && !m.get(100));
    }

    #[test]
    fn epochmask_commit_flips_visibility_atomically() {
        let mut m = EpochMask::from_flags(&[true, true, false, true], 70);
        assert_eq!(m.count_ones(), 3);
        m.begin_batch();
        // the shadow starts as a copy of the active plane
        assert!(m.pending(0) && m.pending(1) && !m.pending(2) && m.pending(3));
        m.set_pending(1, false);
        m.set_pending(69, true);
        // active plane unchanged while the batch edits the shadow
        assert!(m.get(1) && !m.get(69));
        assert_eq!(m.count_ones(), 3);
        m.commit_batch();
        assert!(!m.get(1) && m.get(69));
        assert_eq!(m.count_ones(), 3);
    }

    #[test]
    fn epochmask_abort_discards_the_shadow() {
        let mut m = EpochMask::from_flags(&[true, false], 2);
        m.begin_batch();
        m.set_pending(0, false);
        m.set_pending(1, true);
        m.abort_batch();
        assert!(m.get(0) && !m.get(1));
        // the next batch starts from the committed plane, not the
        // discarded shadow
        m.begin_batch();
        assert!(m.pending(0) && !m.pending(1));
        m.commit_batch();
        assert!(m.get(0) && !m.get(1));
    }

    #[test]
    fn epochmask_grow_mid_batch_adds_dead_rows_to_both_planes() {
        let mut m = EpochMask::from_flags(&[true], 1);
        m.begin_batch();
        m.grow(64);
        assert_eq!(m.capacity(), 65);
        assert!(!m.pending(64) && !m.get(64));
        m.set_pending(64, true);
        m.commit_batch();
        assert!(m.get(0) && m.get(64));
        assert_eq!(m.count_ones(), 2);
    }

    #[test]
    #[should_panic(expected = "nested EpochMask batch")]
    fn epochmask_nested_batch_panics() {
        let mut m = EpochMask::new(8);
        m.begin_batch();
        m.begin_batch();
    }

    #[test]
    fn planeset_roundtrip() {
        let vals: Vec<u64> = (0..XBAR_ROWS as u64)
            .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15) >> 20)
            .collect();
        let ps = PlaneSet::pack(&vals, 44);
        let got = ps.unpack();
        for (r, &v) in vals.iter().enumerate() {
            assert_eq!(got[r], v & ((1 << 44) - 1));
            assert_eq!(ps.value_at(r), v & ((1 << 44) - 1));
        }
    }
}
