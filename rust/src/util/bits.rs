//! Packed bit containers used across the functional PIM engine.
//!
//! The crossbar row axis (1024 rows) packs into `WORDS = 16` u64 words —
//! one cache line per bit-plane, sized so the fixed-width inner loops in
//! `exec::engine` autovectorize. The L1 Pallas kernels keep their own
//! `u32[KERNEL_WORDS]` plane layout (DESIGN.md §Hardware-Adaptation);
//! the PJRT boundary in `runtime::exec` splits each u64 into lo/hi u32
//! halves on gather and recombines on scatter, so the kernel ABI is
//! unchanged by the host-side word width.

/// Crossbar rows (paper Table 3).
pub const XBAR_ROWS: usize = 1024;
/// Crossbar columns (paper Table 3).
pub const XBAR_COLS: usize = 512;
/// Bits per packed plane word (host-side kernel word width).
pub const WORD_BITS: usize = 64;
/// u64 words per bit-plane column.
pub const WORDS: usize = XBAR_ROWS / WORD_BITS;
/// u32 words per bit-plane column in the L1 Pallas kernel ABI (the PJRT
/// literals keep the original u32 packing; see `runtime::exec`).
pub const KERNEL_WORDS: usize = XBAR_ROWS / 32;
/// Bit-planes carried by the generic ALU executables.
pub const PLANES: usize = 64;
/// Crossbars per exported executable invocation (must match python XB_TILE).
pub const XB_TILE: usize = 16;
/// Bits retrieved by one crossbar read (paper Table 3).
pub const XBAR_READ_BITS: usize = 16;

/// A dense 2-D bit matrix, `rows x cols`, row-major, bit-addressable.
/// Used by the cell-accurate crossbar reference model.
#[derive(Clone, PartialEq, Eq)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    data: Vec<u64>,
}

impl BitMatrix {
    /// An all-zero matrix of the given shape.
    pub fn new(rows: usize, cols: usize) -> Self {
        let words_per_row = cols.div_ceil(64);
        BitMatrix {
            rows,
            cols,
            words_per_row,
            data: vec![0; rows * words_per_row],
        }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Bit at (r, c).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        let w = self.data[r * self.words_per_row + c / 64];
        (w >> (c % 64)) & 1 == 1
    }

    /// Set bit (r, c) to `v`.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        let w = &mut self.data[r * self.words_per_row + c / 64];
        if v {
            *w |= 1 << (c % 64);
        } else {
            *w &= !(1 << (c % 64));
        }
    }

    /// Read `n <= 64` bits of row `r` starting at column `c` (LSB-first).
    pub fn read_bits(&self, r: usize, c: usize, n: usize) -> u64 {
        debug_assert!(n <= 64 && c + n <= self.cols);
        let mut v = 0u64;
        for i in 0..n {
            if self.get(r, c + i) {
                v |= 1 << i;
            }
        }
        v
    }

    /// Write `n <= 64` bits of row `r` starting at column `c` (LSB-first).
    pub fn write_bits(&mut self, r: usize, c: usize, n: usize, v: u64) {
        debug_assert!(n <= 64 && c + n <= self.cols);
        for i in 0..n {
            self.set(r, c + i, (v >> i) & 1 == 1);
        }
    }
}

impl std::fmt::Debug for BitMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BitMatrix({}x{})", self.rows, self.cols)
    }
}

/// One bit per crossbar row, packed: a crossbar *column* (e.g. a filter
/// result mask). Same `u64[WORDS]` packing as the engine's bit-planes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RowMask(pub [u64; WORDS]);

impl Default for RowMask {
    fn default() -> Self {
        RowMask([0; WORDS])
    }
}

impl RowMask {
    /// Every row selected.
    pub fn all_ones() -> Self {
        RowMask([u64::MAX; WORDS])
    }

    /// Only the first `n` rows set.
    pub fn first_n(n: usize) -> Self {
        let mut m = RowMask::default();
        for r in 0..n.min(XBAR_ROWS) {
            m.set(r, true);
        }
        m
    }

    /// Whether `row` is selected.
    #[inline]
    pub fn get(&self, row: usize) -> bool {
        debug_assert!(row < XBAR_ROWS, "RowMask::get row {row} out of range");
        (self.0[row / WORD_BITS] >> (row % WORD_BITS)) & 1 == 1
    }

    /// Select or clear `row`.
    #[inline]
    pub fn set(&mut self, row: usize, v: bool) {
        debug_assert!(row < XBAR_ROWS, "RowMask::set row {row} out of range");
        if v {
            self.0[row / WORD_BITS] |= 1 << (row % WORD_BITS);
        } else {
            self.0[row / WORD_BITS] &= !(1 << (row % WORD_BITS));
        }
    }

    /// Number of selected rows.
    pub fn count_ones(&self) -> u32 {
        self.0.iter().map(|w| w.count_ones()).sum()
    }

    /// Row-wise AND.
    pub fn and(&self, o: &RowMask) -> RowMask {
        let mut r = [0u64; WORDS];
        for (i, x) in r.iter_mut().enumerate() {
            *x = self.0[i] & o.0[i];
        }
        RowMask(r)
    }

    /// Row-wise OR.
    pub fn or(&self, o: &RowMask) -> RowMask {
        let mut r = [0u64; WORDS];
        for (i, x) in r.iter_mut().enumerate() {
            *x = self.0[i] | o.0[i];
        }
        RowMask(r)
    }

    /// Row-wise complement.
    pub fn not(&self) -> RowMask {
        let mut r = [0u64; WORDS];
        for (i, x) in r.iter_mut().enumerate() {
            *x = !self.0[i];
        }
        RowMask(r)
    }

    /// Indices of the selected rows, ascending.
    pub fn iter_rows(&self) -> impl Iterator<Item = usize> + '_ {
        (0..XBAR_ROWS).filter(move |&r| self.get(r))
    }
}

/// Bit-plane set of one attribute over one crossbar: `planes[i][w]` holds
/// bit `i` of rows `64w..64w+64`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlaneSet {
    /// Number of bit-planes (attribute width).
    pub nplanes: usize,
    /// The packed planes, LSB first.
    pub planes: Vec<[u64; WORDS]>,
}

impl PlaneSet {
    /// An all-zero plane set `nplanes` wide.
    pub fn zero(nplanes: usize) -> Self {
        PlaneSet {
            nplanes,
            planes: vec![[0; WORDS]; nplanes],
        }
    }

    /// Pack per-row values (LSB-first planes).
    pub fn pack(values: &[u64], nplanes: usize) -> Self {
        debug_assert!(values.len() <= XBAR_ROWS);
        let mut ps = PlaneSet::zero(nplanes);
        for (r, &v) in values.iter().enumerate() {
            for i in 0..nplanes {
                if (v >> i) & 1 == 1 {
                    ps.planes[i][r / WORD_BITS] |= 1 << (r % WORD_BITS);
                }
            }
        }
        ps
    }

    /// Unpack back to per-row values.
    pub fn unpack(&self) -> Vec<u64> {
        let mut vals = vec![0u64; XBAR_ROWS];
        for i in 0..self.nplanes {
            for w in 0..WORDS {
                let mut bits = self.planes[i][w];
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    vals[w * WORD_BITS + b] |= 1 << i;
                    bits &= bits - 1;
                }
            }
        }
        vals
    }

    /// The integer value stored in `row`.
    pub fn value_at(&self, row: usize) -> u64 {
        let mut v = 0u64;
        for i in 0..self.nplanes {
            if (self.planes[i][row / WORD_BITS] >> (row % WORD_BITS)) & 1 == 1 {
                v |= 1 << i;
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitmatrix_rw_roundtrip() {
        let mut m = BitMatrix::new(16, 100);
        m.write_bits(3, 37, 23, 0x5A5A5A);
        assert_eq!(m.read_bits(3, 37, 23), 0x5A5A5A & ((1 << 23) - 1));
        assert_eq!(m.read_bits(2, 37, 23), 0);
    }

    #[test]
    fn bitmatrix_set_get() {
        let mut m = BitMatrix::new(4, 65);
        m.set(1, 64, true);
        assert!(m.get(1, 64));
        m.set(1, 64, false);
        assert!(!m.get(1, 64));
    }

    #[test]
    fn rowmask_ops() {
        let mut a = RowMask::default();
        a.set(0, true);
        a.set(1023, true);
        assert_eq!(a.count_ones(), 2);
        let b = a.not();
        assert_eq!(b.count_ones(), 1022);
        assert_eq!(a.and(&b).count_ones(), 0);
        assert_eq!(a.or(&b).count_ones(), 1024);
        assert_eq!(a.iter_rows().collect::<Vec<_>>(), vec![0, 1023]);
    }

    #[test]
    fn rowmask_first_n() {
        let m = RowMask::first_n(100);
        assert_eq!(m.count_ones(), 100);
        assert!(m.get(99) && !m.get(100));
    }

    #[test]
    fn planeset_roundtrip() {
        let vals: Vec<u64> = (0..XBAR_ROWS as u64)
            .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15) >> 20)
            .collect();
        let ps = PlaneSet::pack(&vals, 44);
        let got = ps.unpack();
        for (r, &v) in vals.iter().enumerate() {
            assert_eq!(got[r], v & ((1 << 44) - 1));
            assert_eq!(ps.value_at(r), v & ((1 << 44) - 1));
        }
    }
}
