//! Deterministic PRNG (xoshiro256** seeded via splitmix64).
//!
//! The TPC-H generator and the property tests must be reproducible across
//! runs and platforms, so we carry our own generator instead of depending
//! on `rand` (not present in the offline vendor set).

/// xoshiro256** PRNG state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the state via splitmix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Independent stream `i` of this generator (for per-table streams).
    pub fn stream(&self, i: u64) -> Rng {
        let mut r = Rng::new(self.s[0] ^ i.wrapping_mul(0xA0761D6478BD642F));
        r.next_u64();
        r
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (upper half of the 64-bit stream).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [lo, hi] inclusive (Lemire-style rejection-free bound).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64();
        }
        let bound = span + 1;
        // widening multiply keeps the distribution uniform enough for data
        // generation (bias < 2^-64 * bound).
        let m = (self.next_u64() as u128).wrapping_mul(bound as u128);
        lo + (m >> 64) as u64
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo.wrapping_add(self.range_u64(0, (hi - lo) as u64) as i64)
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// True with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick one element uniformly.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_u64(0, xs.len() as u64 - 1) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent() {
        let root = Rng::new(7);
        let mut s1 = root.stream(1);
        let mut s2 = root.stream(2);
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut r = Rng::new(1);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(3, 10);
            assert!((3..=10).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 10;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
