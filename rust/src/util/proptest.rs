//! Mini property-testing harness (the offline vendor set has no `proptest`).
//!
//! `check(name, cases, |g| { ... })` runs a closure `cases` times with a
//! deterministic generator; on failure it reports the case seed so the
//! failing input can be reproduced with `replay(seed, f)`.

use super::rng::Rng;

/// Case-local random generator handed to properties.
pub struct Gen {
    /// The underlying deterministic PRNG.
    pub rng: Rng,
    /// This case's seed (printed on failure for `replay`).
    pub seed: u64,
}

impl Gen {
    /// Uniform u64 in [lo, hi] inclusive.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u64(lo as u64, hi as u64) as usize
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    /// A u64 whose bit-width is itself random — exercises narrow values,
    /// wide values, and boundary patterns far more often than uniform u64.
    pub fn skewed_u64(&mut self) -> u64 {
        let bits = self.rng.range_u64(0, 64);
        if bits == 0 {
            return 0;
        }
        let v = self.rng.next_u64();
        if bits == 64 {
            v
        } else {
            v & ((1u64 << bits) - 1)
        }
    }

    /// `len` uniform values in [lo, hi].
    pub fn vec_u64(&mut self, len: usize, lo: u64, hi: u64) -> Vec<u64> {
        (0..len).map(|_| self.u64(lo, hi)).collect()
    }

    /// One element of `xs`, uniformly.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.pick(xs)
    }
}

/// Run `f` on `cases` generated inputs; panic with the reproducing seed on
/// the first failure (failure == panic inside `f`).
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    f: F,
) {
    let base = 0xC0FFEE ^ name.len() as u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Rng::new(seed),
                seed,
            };
            f(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<panic>".into());
            panic!(
                "property '{name}' failed on case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F: FnOnce(&mut Gen)>(seed: u64, f: F) {
    let mut g = Gen {
        rng: Rng::new(seed),
        seed,
    };
    f(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let count = AtomicU64::new(0);
        check("add-commutes", 64, |g| {
            let a = g.skewed_u64();
            let b = g.skewed_u64();
            assert_eq!(a.wrapping_add(b), b.wrapping_add(a));
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 64);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always-fails", 4, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn skewed_values_cover_widths() {
        let mut g = Gen {
            rng: Rng::new(123),
            seed: 123,
        };
        let mut small = false;
        let mut large = false;
        for _ in 0..200 {
            let v = g.skewed_u64();
            small |= v < 16;
            large |= v > (1 << 48);
        }
        assert!(small && large);
    }
}
