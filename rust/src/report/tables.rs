//! Tables 1–6 of the paper's evaluation.

use crate::config::SystemConfig;
use crate::db::layout::DbLayout;
use crate::pim::controller::cost;
use crate::pim::isa::{ColRange, Opcode, PimInstruction};
use crate::query::tpch;

use super::Experiments;

/// Paper Table 1 reference values at SF=1000 for side-by-side printing.
const TABLE1_PAPER: [(&str, u64, u64, u64, f64); 6] = [
    ("PART", 200_000_000, 124, 12, 0.241),
    ("SUPPLIER", 10_000_000, 99, 1, 0.12),
    ("PARTSUPP", 800_000_000, 80, 48, 0.155),
    ("CUSTOMER", 150_000_000, 106, 9, 0.206),
    ("ORDERS", 1_500_000_000, 133, 90, 0.258),
    ("LINEITEM", 6_000_000_000, 191, 358, 0.373),
];

/// Table 1: PIM layout summary for TPC-H at the report SF.
pub fn table1(cfg: &SystemConfig) {
    let layout = DbLayout::build(cfg, &|r| r.records_at_sf(cfg.sim_sf)).unwrap();
    println!("== Table 1: PIM layout summary (SF={}) ==", cfg.report_sf);
    println!(
        "{:<10} {:>14} {:>9} {:>7} {:>7}   {:>9} {:>7} {:>7} (paper)",
        "Relation", "Records", "RowBits", "Pages", "Util%", "RowBits", "Pages", "Util%"
    );
    for (r, paper) in layout.relations.iter().zip(TABLE1_PAPER) {
        println!(
            "{:<10} {:>14} {:>9} {:>7} {:>6.1}%   {:>9} {:>7} {:>6.1}%",
            r.rel.name(),
            r.records_report,
            r.row_bits,
            r.pages_report,
            r.utilization(cfg) * 100.0,
            paper.2,
            paper.3,
            paper.4 * 100.0
        );
    }
    println!(
        "{:<10} {:>14} {:>9} {:>7} {:>6.1}%   {:>9} {:>7} {:>6.1}%",
        "Total",
        "-",
        "-",
        layout.total_pages,
        layout.total_utilization(cfg) * 100.0,
        "-",
        518,
        32.6
    );
    println!("NATION, REGION: DRAM-resident (25 / 5 records)");
}

/// Table 2: PIM-operated relations per query.
pub fn table2() {
    println!("== Table 2: PIM-operated relations per query ==");
    for q in tpch::all_queries() {
        let rels: Vec<&str> = q.rels.iter().map(|r| r.rel.name()).collect();
        let kind = match q.kind {
            crate::query::ast::QueryKind::Full => "full",
            crate::query::ast::QueryKind::FilterOnly => "filter-only",
        };
        println!("{:<8} [{}] {}", q.name, kind, rels.join(", "));
    }
    println!("Q9/Q13/Q18: filter only non-PIM attributes — not evaluated (as in the paper)");
}

/// Table 3: architecture and system configuration.
pub fn table3(cfg: &SystemConfig) {
    println!("== Table 3: system configuration ==");
    for (k, v) in cfg.entries() {
        println!("{k:<28} = {v}");
    }
    println!(
        "derived: xbars/page={} records/page={} pim-ctrls/page={} capacity={} GB",
        cfg.xbars_per_page(),
        cfg.records_per_page(),
        cfg.pim_ctrls_per_page(),
        cfg.pim_capacity() >> 30
    );
}

/// Table 4: instruction characteristics at the paper's reference points.
pub fn table4(cfg: &SystemConfig) {
    println!(
        "== Table 4: instruction cycles / intermediate cells (crossbar {}x{}) ==",
        cfg.xbar_rows, cfg.xbar_cols
    );
    println!(
        "{:<18} {:>24} {:>12}",
        "Instruction", "Cycles(n=32,m=16,imm=0xF0F0F0F0)", "Inter.cells"
    );
    let imm = 0xF0F0_F0F0u64;
    let a = ColRange::new(0, 32);
    let b = ColRange::new(64, 16);
    let b32 = ColRange::new(64, 32);
    let d = ColRange::new(128, 1);
    let rows = cfg.xbar_rows;
    let entries: Vec<(&str, PimInstruction)> = vec![
        ("Equal imm", PimInstruction::with_imm(Opcode::EqImm, a, d, imm)),
        ("Not Equal imm", PimInstruction::with_imm(Opcode::NeImm, a, d, imm)),
        ("Less Than imm", PimInstruction::with_imm(Opcode::LtImm, a, d, imm)),
        ("Greater Than imm", PimInstruction::with_imm(Opcode::GtImm, a, d, imm)),
        ("Add imm", PimInstruction::with_imm(Opcode::AddImm, a, a, imm)),
        ("Equal", PimInstruction::binary(Opcode::Eq, a, b32, d)),
        ("Less Than", PimInstruction::binary(Opcode::Lt, a, b32, d)),
        ("Set/Reset", PimInstruction::unary(Opcode::Set, a, a)),
        ("Bitwise NOT", PimInstruction::unary(Opcode::Not, a, a)),
        ("Bitwise AND", PimInstruction::binary(Opcode::And, a, b32, a)),
        ("Bitwise OR", PimInstruction::binary(Opcode::Or, a, b32, a)),
        ("Addition", PimInstruction::binary(Opcode::Add, a, b32, a)),
        ("Multiply", PimInstruction::binary(Opcode::Mul, a, b, a)),
        ("Reduce Sum", PimInstruction::unary(Opcode::ReduceSum, a, a)),
        ("Reduce Min/Max", PimInstruction::unary(Opcode::ReduceMin, a, a)),
        (
            "Column-Transform",
            PimInstruction::unary(Opcode::ColumnTransform, d, d),
        ),
    ];
    for (name, i) in entries {
        let c = cost(&i, rows);
        println!(
            "{:<18} {:>14} (col {:>8} + row {:>8}) {:>8}",
            name,
            c.total_cycles(),
            c.col_cycles,
            c.row_cycles,
            c.intermediate_cells
        );
    }
}

/// Table 5 rendered to a string (golden-snapshot tested: the rendering is
/// deterministic for a fixed seed/scale and independent of the host
/// `parallelism` knob).
pub fn table5_string(exps: &Experiments) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    writeln!(s, "== Table 5: PIM logic cycles by type (per crossbar) ==").unwrap();
    writeln!(
        s,
        "{:<8} {:>8} {:>8} {:>10} {:>12} {:>12} {:>7}",
        "Query", "Filter", "Arith", "ColTrans", "Agg-col", "Agg-row", "Inter"
    )
    .unwrap();
    for p in &exps.pairs {
        let c = &p.pim.metrics.cycles;
        writeln!(
            s,
            "{:<8} {:>8} {:>8} {:>10} {:>12} {:>12} {:>7}",
            p.query.name,
            c.filter,
            c.arith,
            c.col_transform,
            c.agg_col,
            c.agg_row,
            p.pim.metrics.inter_cells
        )
        .unwrap();
    }
    s
}

/// Table 5: per-crossbar bulk-bitwise cycles by type + intermediate cells.
pub fn table5(exps: &Experiments) {
    print!("{}", table5_string(exps));
}

/// Table 6 rendered to a string (see [`table5_string`]).
pub fn table6_string(exps: &Experiments) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    writeln!(s, "== Table 6: endurance contribution breakdown (max row) ==").unwrap();
    writeln!(
        s,
        "{:<8} {:>8} {:>8} {:>10} {:>9} {:>9}",
        "Query", "Filter%", "Arith%", "ColTrans%", "AggCol%", "AggRow%"
    )
    .unwrap();
    for p in &exps.pairs {
        let b = p.pim.metrics.endurance_breakdown;
        writeln!(
            s,
            "{:<8} {:>7.1}% {:>7.1}% {:>9.1}% {:>8.1}% {:>8.1}%",
            p.query.name,
            b[0] * 100.0,
            b[1] * 100.0,
            b[2] * 100.0,
            b[3] * 100.0,
            b[4] * 100.0
        )
        .unwrap();
    }
    s
}

/// Table 6: endurance contribution breakdown at the hottest row.
pub fn table6(exps: &Experiments) {
    print!("{}", table6_string(exps));
}

/// Optimizer impact rendered to a string (golden-snapshot friendly):
/// per query, compiled-vs-executed instruction and cycle counts and the
/// intermediate-cell peaks, at the opt level the runs used.
pub fn table_opt_string(exps: &Experiments) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    writeln!(
        s,
        "== Optimizer impact (-{}): compiled -> executed ==",
        exps.cfg.opt_level
    )
    .unwrap();
    writeln!(
        s,
        "{:<8} {:>7} {:>7} {:>10} {:>10} {:>8} {:>6} {:>6}",
        "Query", "Steps", "Steps'", "Cycles", "Cycles'", "Saved%", "Inter", "Inter'"
    )
    .unwrap();
    for p in &exps.pairs {
        let o = &p.pim.metrics.opt;
        let saved = if o.cycles_before > 0 {
            100.0 * (o.cycles_before - o.cycles_after) as f64 / o.cycles_before as f64
        } else {
            0.0
        };
        writeln!(
            s,
            "{:<8} {:>7} {:>7} {:>10} {:>10} {:>7.1}% {:>6} {:>6}",
            p.query.name,
            o.steps_before,
            o.steps_after,
            o.cycles_before,
            o.cycles_after,
            saved,
            o.inter_before,
            o.inter_after
        )
        .unwrap();
    }
    s
}

/// Optimizer impact: what the `-O` pass pipeline saved per query.
pub fn table_opt(exps: &Experiments) {
    print!("{}", table_opt_string(exps));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_tables_print_without_panic() {
        let cfg = SystemConfig::default();
        table1(&cfg);
        table2();
        table3(&cfg);
        table4(&cfg);
    }

    #[test]
    fn table_strings_have_headers() {
        let exps = Experiments {
            cfg: SystemConfig::default(),
            pairs: vec![],
        };
        assert!(table5_string(&exps).starts_with("== Table 5"));
        assert!(table6_string(&exps).starts_with("== Table 6"));
        assert!(table_opt_string(&exps).starts_with("== Optimizer impact (-O2)"));
    }

    #[test]
    fn table1_reference_matches_schema_counts() {
        for (name, records, _, _, _) in TABLE1_PAPER {
            let rel = crate::db::schema::PIM_RELATIONS
                .iter()
                .find(|r| r.name() == name)
                .unwrap();
            assert_eq!(rel.records_at_sf(1000.0), records);
        }

    }
}
