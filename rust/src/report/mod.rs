//! Experiment registry: regenerates every table and figure of the paper's
//! evaluation (§5–§6). See DESIGN.md §3 for the experiment index.

pub mod figures;
pub mod tables;

use crate::api::{Pimdb, QuerySource};
use crate::config::SystemConfig;
use crate::db::dbgen::Database;
use crate::error::PimdbError;
use crate::exec::metrics::RunReport;
use crate::exec::{baseline, pimdb};
use crate::query::ast::{Query, QueryKind};
use crate::query::tpch;

/// One query's PIMDB-vs-baseline pair.
pub struct QueryPair {
    /// The executed query.
    pub query: Query,
    /// PIMDB engine report.
    pub pim: RunReport,
    /// Column-store baseline report.
    pub base: RunReport,
}

impl QueryPair {
    /// Baseline-over-PIMDB execution-time ratio (Fig. 8).
    pub fn speedup(&self) -> f64 {
        self.base.metrics.exec_time_s / self.pim.metrics.exec_time_s.max(1e-15)
    }

    /// Baseline-over-PIMDB LLC-miss ratio (Fig. 8).
    pub fn llc_reduction(&self) -> f64 {
        self.base.metrics.llc_misses as f64 / self.pim.metrics.llc_misses.max(1) as f64
    }

    /// Baseline-over-PIMDB total-energy ratio (Figs. 11-12).
    pub fn energy_reduction(&self) -> f64 {
        self.base.metrics.total_energy_pj() / self.pim.metrics.total_energy_pj().max(1e-12)
    }
}

/// All queries executed on both engines — the shared input of Figures
/// 8–15 and Tables 5–6.
pub struct Experiments {
    /// The configuration the runs used.
    pub cfg: SystemConfig,
    /// One pair per evaluated query, in paper order.
    pub pairs: Vec<QueryPair>,
}

impl Experiments {
    /// Run all 19 queries on PIMDB and the baseline over one service
    /// handle (the PIM database copy loads once, as in the paper; each
    /// query is prepared through the plan cache and executed).
    pub fn run(cfg: &SystemConfig, engine: pimdb::EngineKind) -> Result<Experiments, PimdbError> {
        let handle = Pimdb::open(cfg.clone(), Database::generate(cfg.sim_sf, 42))?;
        let mut pairs = Vec::new();
        for q in tpch::all_queries() {
            let pim = handle
                .prepare(QuerySource::Ast(&q))?
                .execute_on(engine)?
                .into_report();
            let base = baseline::run_query(cfg, handle.database(), &q);
            pairs.push(QueryPair {
                query: q,
                pim,
                base,
            });
        }
        Ok(Experiments {
            cfg: cfg.clone(),
            pairs,
        })
    }

    /// The filter-only query pairs.
    pub fn filter_only(&self) -> impl Iterator<Item = &QueryPair> {
        self.pairs
            .iter()
            .filter(|p| p.query.kind == QueryKind::FilterOnly)
    }

    /// The full (in-PIM aggregation) query pairs.
    pub fn full(&self) -> impl Iterator<Item = &QueryPair> {
        self.pairs
            .iter()
            .filter(|p| p.query.kind == QueryKind::Full)
    }
}

/// Experiment ids accepted by `pimdb report --exp`.
pub const EXPERIMENTS: [&str; 17] = [
    "table1",
    "table2",
    "table3",
    "table4",
    "table5",
    "table6",
    "opt",
    "fig8",
    "fig9",
    "fig10",
    "fig11",
    "fig12",
    "fig13",
    "fig14",
    "fig15",
    "ablation-rowpar",
    "calibration",
];

/// Whether an experiment needs the full query-pair runs.
pub fn needs_runs(exp: &str) -> bool {
    !matches!(exp, "table1" | "table2" | "table3" | "table4" | "fig10")
}

/// Print one experiment. `exps` must be Some for run-based experiments.
pub fn print_experiment(
    exp: &str,
    cfg: &SystemConfig,
    exps: Option<&Experiments>,
) -> Result<(), String> {
    match exp {
        "table1" => tables::table1(cfg),
        "table2" => tables::table2(),
        "table3" => tables::table3(cfg),
        "table4" => tables::table4(cfg),
        "table5" => tables::table5(exps.ok_or("needs runs")?),
        "table6" => tables::table6(exps.ok_or("needs runs")?),
        "opt" => tables::table_opt(exps.ok_or("needs runs")?),
        "fig8" => figures::fig8(exps.ok_or("needs runs")?),
        "fig9" => figures::fig9(exps.ok_or("needs runs")?),
        "fig10" => figures::fig10(cfg),
        "fig11" => figures::fig11(exps.ok_or("needs runs")?),
        "fig12" => figures::fig12(exps.ok_or("needs runs")?),
        "fig13" => figures::fig13(exps.ok_or("needs runs")?),
        "fig14" => figures::fig14(exps.ok_or("needs runs")?),
        "fig15" => figures::fig15(exps.ok_or("needs runs")?),
        "ablation-rowpar" => figures::ablation_rowpar(exps.ok_or("needs runs")?),
        "calibration" => figures::calibration(exps.ok_or("needs runs")?),
        other => return Err(format!("unknown experiment '{other}'")),
    }
    Ok(())
}
