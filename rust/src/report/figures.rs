//! Figures 8–15 plus the §6.1 ablation and calibration studies.

use crate::config::SystemConfig;
use crate::pim::area;

use crate::util::stats::eng;

use super::Experiments;

/// Filter fraction of total query time for filter-only queries, used for
/// the estimated-total-speedup series of Fig. 8(a). The paper takes
/// per-query fractions from Kepe et al. [20]; we use their reported
/// average (~45%) as a single substitute fraction (documented in
/// EXPERIMENTS.md).
const FILTER_FRACTION: f64 = 0.45;

/// Fig. 8: speedup and LLC-miss reduction vs the baseline.
pub fn fig8(exps: &Experiments) {
    println!("== Fig 8(a): filter-only queries ==");
    println!(
        "{:<8} {:>10} {:>12} {:>12} {:>18}",
        "Query", "Speedup", "LLC-reduct", "PIM time", "Est.total-speedup"
    );
    for p in exps.filter_only() {
        let s = p.speedup();
        let est = 1.0 / ((1.0 - FILTER_FRACTION) + FILTER_FRACTION / s);
        println!(
            "{:<8} {:>9.2}x {:>11.2}x {:>11}s {:>17.2}x",
            p.query.name,
            s,
            p.llc_reduction(),
            eng(p.pim.metrics.exec_time_s),
            est
        );
    }
    println!("== Fig 8(b): full queries ==");
    for p in exps.full() {
        println!(
            "{:<8} {:>9.1}x {:>11.2}x {:>11}s",
            p.query.name,
            p.speedup(),
            p.llc_reduction(),
            eng(p.pim.metrics.exec_time_s)
        );
    }
    println!("paper bands: filter 1.6x-18x (Q11 ~0.82x), full 62x-787x");
}

/// Fig. 9: PIMDB execution-time breakdown.
pub fn fig9(exps: &Experiments) {
    println!("== Fig 9: PIMDB execution-time breakdown ==");
    println!(
        "{:<8} {:>10} {:>8} {:>8} {:>8}",
        "Query", "Total", "PIM%", "Read%", "Other%"
    );
    for p in &exps.pairs {
        let m = &p.pim.metrics;
        let tot = (m.pim_time_s + m.read_time_s + m.other_time_s).max(1e-15);
        println!(
            "{:<8} {:>9}s {:>7.1}% {:>7.1}% {:>7.1}%",
            p.query.name,
            eng(m.exec_time_s),
            m.pim_time_s / tot * 100.0,
            m.read_time_s / tot * 100.0,
            m.other_time_s / tot * 100.0
        );
    }
    println!("paper: read dominates filter-only (>99%); Q1/Q6 read 70%/55%");
}

/// Fig. 10: PIM module chip area breakdown.
pub fn fig10(cfg: &SystemConfig) {
    let a = area::chip_area(cfg);
    println!("== Fig 10: PIM chip area breakdown ==");
    for (label, mm2) in a.breakdown() {
        println!(
            "{:<22} {:>10.2} mm^2 ({:>5.2}%)",
            label,
            mm2,
            mm2 / a.total_mm2() * 100.0
        );
    }
    println!(
        "total {:.1} mm^2; PIM controllers {:.3}% (paper: 0.17%)",
        a.total_mm2(),
        a.pim_ctrl_fraction() * 100.0
    );
}

/// Fig. 11: energy saving over the baseline.
pub fn fig11(exps: &Experiments) {
    println!("== Fig 11: PIMDB energy saving over baseline ==");
    println!("{:<8} {:>12} {:>14} {:>14}", "Query", "Saving", "PIMDB", "Baseline");
    for p in &exps.pairs {
        println!(
            "{:<8} {:>11.2}x {:>13}J {:>13}J",
            p.query.name,
            p.energy_reduction(),
            eng(p.pim.metrics.total_energy_pj() * 1e-12),
            eng(p.base.metrics.total_energy_pj() * 1e-12)
        );
    }
    println!("paper bands: filter-only 0.88x-15.3x, full 1.14x / 15.8x");
}

/// Fig. 12: PIMDB system energy breakdown (host / DRAM / PIM).
pub fn fig12(exps: &Experiments) {
    println!("== Fig 12: PIMDB system energy breakdown ==");
    println!(
        "{:<8} {:>8} {:>8} {:>8}",
        "Query", "Host%", "DRAM%", "PIM%"
    );
    for p in &exps.pairs {
        let m = &p.pim.metrics;
        let tot = m.total_energy_pj().max(1e-12);
        println!(
            "{:<8} {:>7.1}% {:>7.1}% {:>7.1}%",
            p.query.name,
            m.host_energy_pj / tot * 100.0,
            m.dram_energy_pj / tot * 100.0,
            m.pim_energy.total_pj() / tot * 100.0
        );
    }
}

/// Fig. 13: PIM module energy breakdown.
pub fn fig13(exps: &Experiments) {
    println!("== Fig 13: PIM module energy breakdown ==");
    println!(
        "{:<8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "Query", "Logic%", "Read%", "Write%", "Ctrl%", "IO%"
    );
    for p in &exps.pairs {
        let e = &p.pim.metrics.pim_energy;
        let tot = e.total_pj().max(1e-12);
        println!(
            "{:<8} {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%",
            p.query.name,
            e.logic_pj / tot * 100.0,
            e.read_pj / tot * 100.0,
            e.write_pj / tot * 100.0,
            e.ctrl_pj / tot * 100.0,
            e.io_pj / tot * 100.0
        );
    }
    println!("paper: >99% stateful logic for full queries");
}

/// Fig. 14: peak / average / theoretical chip power.
pub fn fig14(exps: &Experiments) {
    println!("== Fig 14: PIM chip power ==");
    println!(
        "{:<8} {:>10} {:>10} {:>14}",
        "Query", "Peak(W)", "Avg(W)", "Theoretical(W)"
    );
    for p in &exps.pairs {
        let m = &p.pim.metrics;
        println!(
            "{:<8} {:>10.2} {:>10.3} {:>14.1}",
            p.query.name, m.peak_chip_w, m.avg_chip_w, m.theoretical_chip_w
        );
    }
    println!(
        "all-crossbars bound: {:.0} W/chip (paper: ~730 W); measured peaks ≤125 W, avg ≤10 W",
        crate::pim::power::theoretical_peak_all_xbars_chip_w(&exps.cfg)
    );
}

/// Fig. 15: required endurance for ten years at 100% duty cycle.
pub fn fig15(exps: &Experiments) {
    println!("== Fig 15: required endurance, 10-year 100% duty cycle ==");
    println!(
        "{:<8} {:>14} {:>16} {:>12}",
        "Query", "ops/cell/exec", "10yr writes/cell", "vs 1e12?"
    );
    for p in &exps.pairs {
        let m = &p.pim.metrics;
        println!(
            "{:<8} {:>14.4} {:>16} {:>12}",
            p.query.name,
            m.ops_per_cell,
            eng(m.required_endurance_10yr),
            if m.required_endurance_10yr <= 1e12 {
                "ok"
            } else {
                "EXCEEDS"
            }
        );
    }
    println!("paper: all within RRAM 1e12 except Q22_sub (small relation, frequent reuse)");
}

/// §6.1 ablation: allow row-wise operations on multiple columns in any
/// combination (increasing row-move bandwidth only). The paper reports
/// 80–86% lower full-query bulk-bitwise latency.
pub fn ablation_rowpar(exps: &Experiments) {
    println!("== Ablation: unrestricted row-wise column parallelism ==");
    println!(
        "{:<8} {:>14} {:>14} {:>10}",
        "Query", "logic cycles", "rowpar cycles", "reduction"
    );
    for p in exps.full() {
        let c = &p.pim.metrics.cycles;
        let restricted = c.total();
        // row-wise moves run all bit columns of a value in parallel:
        // agg-row and col-transform cycles shrink by the moved width
        // (sum width ~ n+levels/2; take the per-query structural factor
        // from the measured row/col split)
        let width = (c.agg_row as f64 / (2046.0 * 10.0)).max(1.0); // ≈ avg n
        let rowpar = c.filter + c.arith + c.agg_col
            + (c.agg_row as f64 / width.max(1.0)) as u64
            + c.col_transform / 16;
        println!(
            "{:<8} {:>14} {:>14} {:>9.1}%",
            p.query.name,
            restricted,
            rowpar,
            (1.0 - rowpar as f64 / restricted as f64) * 100.0
        );
    }
    println!("paper: 80-86% bulk-bitwise latency reduction on full queries");
}

/// Calibration against published TPC-H SF=1000 systems (paper §6.1: Dell
/// full-disclosure reports [9], [10]). Published per-query times are
/// order-of-magnitude estimates from the reports' throughput runs.
pub fn calibration(exps: &Experiments) {
    // (query, [9] seconds, [10] seconds) — estimated from the FDRs
    let published = [("Q1", 9.0, 8.0), ("Q6", 2.5, 1.5)];
    println!("== Calibration vs published TPC-H systems (SF=1000) ==");
    println!(
        "{:<8} {:>12} {:>12} {:>12} (paper: Q1 9.3x/8.2x, Q6 19.6x/11.6x)",
        "Query", "PIMDB(s)", "vs [9]", "vs [10]"
    );
    for (name, t9, t10) in published {
        if let Some(p) = exps.pairs.iter().find(|p| p.query.name == name) {
            let t = p.pim.metrics.exec_time_s;
            println!(
                "{:<8} {:>12} {:>11.1}x {:>11.1}x",
                name,
                eng(t),
                t9 / t,
                t10 / t
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_prints() {
        fig10(&SystemConfig::default());
    }

    #[test]
    fn filter_fraction_sane() {
        assert!((0.1..0.9).contains(&FILTER_FRACTION));
    }
}
