//! TPC-H schema with PIM encodings (paper §5.1, Table 1).
//!
//! Attributes kept in the PIM copy use compact encodings that preserve the
//! PIM operations run on them: dictionary encoding (equality-class
//! predicates, incl. LIKE expanded over the dictionary) and leading-zero
//! suppression (all comparisons/arithmetic). Large text attributes (NAME,
//! ADDRESS, COMMENT) are excluded from the PIM copy, as in the paper.
//! Signed values (ACCTBAL) are offset-encoded so unsigned in-memory
//! comparison is order-preserving.

/// Relation identifiers for the six PIM-resident relations plus the two
/// DRAM-resident small relations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RelId {
    /// PART (PIM-resident).
    Part,
    /// SUPPLIER (PIM-resident).
    Supplier,
    /// PARTSUPP (PIM-resident).
    Partsupp,
    /// CUSTOMER (PIM-resident).
    Customer,
    /// ORDERS (PIM-resident).
    Orders,
    /// LINEITEM (PIM-resident).
    Lineitem,
    /// NATION (small, DRAM-resident dimension).
    Nation,
    /// REGION (small, DRAM-resident dimension).
    Region,
}

/// The six relations kept in the PIM modules, in layout order.
pub const PIM_RELATIONS: [RelId; 6] = [
    RelId::Part,
    RelId::Supplier,
    RelId::Partsupp,
    RelId::Customer,
    RelId::Orders,
    RelId::Lineitem,
];

impl RelId {
    /// Upper-case TPC-H relation name.
    pub fn name(&self) -> &'static str {
        match self {
            RelId::Part => "PART",
            RelId::Supplier => "SUPPLIER",
            RelId::Partsupp => "PARTSUPP",
            RelId::Customer => "CUSTOMER",
            RelId::Orders => "ORDERS",
            RelId::Lineitem => "LINEITEM",
            RelId::Nation => "NATION",
            RelId::Region => "REGION",
        }
    }

    /// Records at scale factor `sf` (TPC-H spec §4.2.5).
    pub fn records_at_sf(&self, sf: f64) -> u64 {
        let base = match self {
            RelId::Part => 200_000.0,
            RelId::Supplier => 10_000.0,
            RelId::Partsupp => 800_000.0,
            RelId::Customer => 150_000.0,
            RelId::Orders => 1_500_000.0,
            RelId::Lineitem => 6_000_000.0, // ~exact enough for layout math
            RelId::Nation => return 25,
            RelId::Region => return 5,
        };
        (base * sf).round().max(1.0) as u64
    }

    /// Whether the relation has a PIM copy.
    pub fn in_pim(&self) -> bool {
        !matches!(self, RelId::Nation | RelId::Region)
    }
}

/// Attribute encoding in the PIM copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    /// Raw unsigned integer, leading-zero suppressed to `bits`.
    Uint,
    /// Dictionary id over a fixed vocabulary (equality-class predicates).
    Dict,
    /// Days since 1992-01-01 (orders well with unsigned compare).
    Date,
    /// Fixed-point currency in cents, offset by `offset` to stay unsigned.
    Money { offset: i64 },
}

/// One attribute of a PIM relation.
#[derive(Clone, Copy, Debug)]
pub struct Attr {
    /// Lower-case TPC-H attribute name (e.g. `l_shipdate`).
    pub name: &'static str,
    /// Storage encoding in the PIM copy.
    pub enc: Encoding,
    /// Encoded width in bits at the report scale factor (SF=1000).
    pub bits: usize,
}

impl Attr {
    const fn uint(name: &'static str, bits: usize) -> Attr {
        Attr {
            name,
            enc: Encoding::Uint,
            bits,
        }
    }
    const fn dict(name: &'static str, bits: usize) -> Attr {
        Attr {
            name,
            enc: Encoding::Dict,
            bits,
        }
    }
    const fn date(name: &'static str) -> Attr {
        Attr {
            name,
            enc: Encoding::Date,
            bits: 12,
        }
    }
    const fn money(name: &'static str, bits: usize, offset: i64) -> Attr {
        Attr {
            name,
            enc: Encoding::Money { offset },
            bits,
        }
    }
}

const PART_ATTRS: [Attr; 7] = [
    Attr::uint("p_partkey", 28),
    Attr::dict("p_mfgr", 3),
    Attr::dict("p_brand", 5),
    Attr::dict("p_type", 8),
    Attr::uint("p_size", 6),
    Attr::dict("p_container", 6),
    Attr::money("p_retailprice", 21, 0),
];

const SUPPLIER_ATTRS: [Attr; 5] = [
    Attr::uint("s_suppkey", 24),
    Attr::uint("s_nationkey", 5),
    Attr::dict("s_phone_cc", 6),
    Attr::uint("s_phone_rest", 36), // local digits, stored numerically
    Attr::money("s_acctbal", 21, 100_000),
];

const PARTSUPP_ATTRS: [Attr; 4] = [
    Attr::uint("ps_partkey", 28),
    Attr::uint("ps_suppkey", 24),
    Attr::uint("ps_availqty", 14),
    Attr::money("ps_supplycost", 17, 0),
];

const CUSTOMER_ATTRS: [Attr; 6] = [
    Attr::uint("c_custkey", 28),
    Attr::uint("c_nationkey", 5),
    Attr::dict("c_phone_cc", 6),
    Attr::uint("c_phone_rest", 36), // local digits, stored numerically
    Attr::money("c_acctbal", 21, 100_000),
    Attr::dict("c_mktsegment", 3),
];

const ORDERS_ATTRS: [Attr; 7] = [
    Attr::uint("o_orderkey", 33),
    Attr::uint("o_custkey", 28),
    Attr::dict("o_orderstatus", 2),
    Attr::money("o_totalprice", 26, 0),
    Attr::date("o_orderdate"),
    Attr::dict("o_orderpriority", 3),
    Attr::uint("o_shippriority", 1),
];

const LINEITEM_ATTRS: [Attr; 15] = [
    Attr::uint("l_orderkey", 33),
    Attr::uint("l_partkey", 28),
    Attr::uint("l_suppkey", 24),
    Attr::uint("l_linenumber", 3),
    Attr::uint("l_quantity", 6),
    Attr::money("l_extendedprice", 24, 0),
    Attr::uint("l_discount", 4),
    Attr::uint("l_tax", 4),
    Attr::dict("l_returnflag", 2),
    Attr::dict("l_linestatus", 1),
    Attr::date("l_shipdate"),
    Attr::date("l_commitdate"),
    Attr::date("l_receiptdate"),
    Attr::dict("l_shipinstruct", 2),
    Attr::dict("l_shipmode", 3),
];

/// PIM-resident attributes per relation (paper: NAME/ADDRESS/COMMENT
/// dropped; a 1-bit VALID column is appended by the layout).
pub fn attrs(rel: RelId) -> &'static [Attr] {
    match rel {
        RelId::Part => &PART_ATTRS,
        RelId::Supplier => &SUPPLIER_ATTRS,
        RelId::Partsupp => &PARTSUPP_ATTRS,
        RelId::Customer => &CUSTOMER_ATTRS,
        RelId::Orders => &ORDERS_ATTRS,
        RelId::Lineitem => &LINEITEM_ATTRS,
        RelId::Nation | RelId::Region => &[],
    }
}

/// Bits per record in the PIM copy, including the VALID column.
pub fn row_bits(rel: RelId) -> usize {
    attrs(rel).iter().map(|a| a.bits).sum::<usize>() + 1
}

/// Look up one attribute of `rel` by name.
pub fn attr(rel: RelId, name: &str) -> Option<Attr> {
    attrs(rel).iter().find(|a| a.name == name).copied()
}

/// Position of attribute `name` within `rel`'s schema order.
pub fn attr_index(rel: RelId, name: &str) -> Option<usize> {
    attrs(rel).iter().position(|a| a.name == name)
}

// ---------------------------------------------------------------------------
// dictionaries (TPC-H spec §4.2.2 seed lists)
// ---------------------------------------------------------------------------

/// p_type first words (syllable 1).
pub const TYPE_S1: [&str; 6] = ["STANDARD", "SMALL", "MEDIUM", "LARGE", "ECONOMY", "PROMO"];
/// p_type second words (syllable 2).
pub const TYPE_S2: [&str; 5] = ["ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED"];
/// p_type third words (syllable 3).
pub const TYPE_S3: [&str; 5] = ["TIN", "NICKEL", "BRASS", "STEEL", "COPPER"];
/// p_container first words.
pub const CONTAINER_S1: [&str; 5] = ["SM", "MED", "LG", "JUMBO", "WRAP"];
/// p_container second words.
pub const CONTAINER_S2: [&str; 8] = ["CASE", "BOX", "BAG", "JAR", "PKG", "PACK", "CAN", "DRUM"];
/// c_mktsegment dictionary.
pub const SEGMENTS: [&str; 5] = ["AUTOMOBILE", "BUILDING", "FURNITURE", "MACHINERY", "HOUSEHOLD"];
/// o_orderpriority dictionary.
pub const PRIORITIES: [&str; 5] = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"];
/// l_shipmode dictionary.
pub const SHIPMODES: [&str; 7] = ["REG AIR", "AIR", "RAIL", "SHIP", "TRUCK", "MAIL", "FOB"];
/// l_shipinstruct dictionary.
pub const INSTRUCTIONS: [&str; 4] = ["DELIVER IN PERSON", "COLLECT COD", "NONE", "TAKE BACK RETURN"];
/// l_returnflag dictionary.
pub const RETURNFLAGS: [&str; 3] = ["R", "A", "N"];
/// l_linestatus dictionary.
pub const LINESTATUS: [&str; 2] = ["O", "F"];
/// o_orderstatus dictionary.
pub const ORDERSTATUS: [&str; 3] = ["F", "O", "P"];

/// p_type dictionary id: s1*25 + s2*5 + s3 (150 values).
pub fn type_id(s1: usize, s2: usize, s3: usize) -> u64 {
    (s1 * 25 + s2 * 5 + s3) as u64
}

/// Type ids matching `LIKE '%<s3 word>'` (e.g. '%BRASS').
pub fn type_ids_ending_with(s3_word: &str) -> Vec<u64> {
    let s3 = TYPE_S3.iter().position(|&w| w == s3_word).expect("s3 word");
    (0..6)
        .flat_map(|s1| (0..5).map(move |s2| type_id(s1, s2, s3)))
        .collect()
}

/// Type ids matching `LIKE '<s1 word>%'` (e.g. 'PROMO%').
pub fn type_ids_starting_with(s1_word: &str) -> Vec<u64> {
    let s1 = TYPE_S1.iter().position(|&w| w == s1_word).expect("s1 word");
    (0..5)
        .flat_map(|s2| (0..5).map(move |s3| type_id(s1, s2, s3)))
        .collect()
}

/// Type ids matching `LIKE '<s1> <s2>%'` (e.g. 'MEDIUM POLISHED%').
pub fn type_ids_with_prefix2(s1_word: &str, s2_word: &str) -> Vec<u64> {
    let s1 = TYPE_S1.iter().position(|&w| w == s1_word).expect("s1 word");
    let s2 = TYPE_S2.iter().position(|&w| w == s2_word).expect("s2 word");
    (0..5).map(|s3| type_id(s1, s2, s3)).collect()
}

/// Exact p_type id from the full string, e.g. "ECONOMY ANODIZED STEEL".
pub fn type_id_of(s: &str) -> u64 {
    let parts: Vec<&str> = s.split(' ').collect();
    let s1 = TYPE_S1.iter().position(|&w| w == parts[0]).expect("s1");
    let s2 = TYPE_S2.iter().position(|&w| w == parts[1]).expect("s2");
    let s3 = TYPE_S3.iter().position(|&w| w == parts[2]).expect("s3");
    type_id(s1, s2, s3)
}

/// Brand id: "Brand#MN" with M,N in 1..=5 -> (M-1)*5 + (N-1).
pub fn brand_id(brand: &str) -> u64 {
    let digits = brand.trim_start_matches("Brand#");
    let m = digits.as_bytes()[0] - b'1';
    let n = digits.as_bytes()[1] - b'1';
    (m as u64) * 5 + n as u64
}

/// Container id: "<s1> <s2>" -> s1*8 + s2 (40 values).
pub fn container_id(c: &str) -> u64 {
    let (a, b) = c.split_once(' ').expect("container");
    let s1 = CONTAINER_S1.iter().position(|&w| w == a).expect("c s1") as u64;
    let s2 = CONTAINER_S2.iter().position(|&w| w == b).expect("c s2") as u64;
    s1 * 8 + s2
}

/// c_mktsegment dictionary id (panics on unknown segment).
pub fn segment_id(s: &str) -> u64 {
    SEGMENTS.iter().position(|&w| w == s).expect("segment") as u64
}

/// l_shipmode dictionary id (panics on unknown mode).
pub fn shipmode_id(s: &str) -> u64 {
    SHIPMODES.iter().position(|&w| w == s).expect("shipmode") as u64
}

/// l_shipinstruct dictionary id (panics on unknown instruction).
pub fn instruct_id(s: &str) -> u64 {
    INSTRUCTIONS.iter().position(|&w| w == s).expect("instruct") as u64
}

/// l_returnflag dictionary id (panics on unknown flag).
pub fn returnflag_id(s: &str) -> u64 {
    RETURNFLAGS.iter().position(|&w| w == s).expect("returnflag") as u64
}

/// o_orderstatus dictionary id (panics on unknown status).
pub fn orderstatus_id(s: &str) -> u64 {
    ORDERSTATUS.iter().position(|&w| w == s).expect("orderstatus") as u64
}

// ---------------------------------------------------------------------------
// nations / regions (TPC-H spec fixed content)
// ---------------------------------------------------------------------------

/// The five TPC-H region names, in regionkey order.
pub const REGIONS: [&str; 5] = ["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"];

/// (name, regionkey) in nationkey order 0..24.
pub const NATIONS: [(&str, usize); 25] = [
    ("ALGERIA", 0),
    ("ARGENTINA", 1),
    ("BRAZIL", 1),
    ("CANADA", 1),
    ("EGYPT", 4),
    ("ETHIOPIA", 0),
    ("FRANCE", 3),
    ("GERMANY", 3),
    ("INDIA", 2),
    ("INDONESIA", 2),
    ("IRAN", 4),
    ("IRAQ", 4),
    ("JAPAN", 2),
    ("JORDAN", 4),
    ("KENYA", 0),
    ("MOROCCO", 0),
    ("MOZAMBIQUE", 0),
    ("PERU", 1),
    ("CHINA", 2),
    ("ROMANIA", 3),
    ("SAUDI ARABIA", 4),
    ("VIETNAM", 2),
    ("RUSSIA", 3),
    ("UNITED KINGDOM", 3),
    ("UNITED STATES", 1),
];

/// Nation key of `name` (panics on unknown nation).
pub fn nation_id(name: &str) -> u64 {
    NATIONS.iter().position(|&(n, _)| n == name).expect("nation") as u64
}

/// Nation keys belonging to a region name (the DRAM-side dimension lookup
/// the compiler folds into IN-set predicates).
pub fn nations_in_region(region: &str) -> Vec<u64> {
    let r = REGIONS.iter().position(|&w| w == region).expect("region");
    NATIONS
        .iter()
        .enumerate()
        .filter(|(_, &(_, reg))| reg == r)
        .map(|(i, _)| i as u64)
        .collect()
}

// ---------------------------------------------------------------------------
// dates
// ---------------------------------------------------------------------------

/// TPC-H date epoch: 1992-01-01 (day 0).
pub const EPOCH: (i64, i64, i64) = (1992, 1, 1);

/// Days-from-civil (Howard Hinnant's algorithm), then offset to the epoch.
fn days_from_civil(y: i64, m: i64, d: i64) -> i64 {
    let y = if m <= 2 { y - 1 } else { y };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = y - era * 400;
    let mp = (m + 9) % 12;
    let doy = (153 * mp + 2) / 5 + d - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    era * 146_097 + doe - 719_468
}

/// Encode a calendar date as days since 1992-01-01.
pub fn date(y: i64, m: i64, d: i64) -> u64 {
    let epoch = days_from_civil(EPOCH.0, EPOCH.1, EPOCH.2);
    (days_from_civil(y, m, d) - epoch) as u64
}

/// Civil-from-days (the inverse of [`days_from_civil`], same reference
/// algorithm): `z` counts days since 1970-01-01.
fn civil_from_days(z: i64) -> (i64, i64, i64) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = z - era * 146_097;
    let yoe = (doe - doe / 1460 + doe / 36524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// Decode an encoded date (days since 1992-01-01) back to `(y, m, d)` —
/// the typed-result inverse of [`date`].
pub fn date_ymd(days: u64) -> (i64, i64, i64) {
    civil_from_days(days_from_civil(EPOCH.0, EPOCH.1, EPOCH.2) + days as i64)
}

/// Decode a dictionary id back to its word, per attribute — the
/// typed-result inverse of the `*_id` encoders above. `None` when the
/// attribute has no known vocabulary or the id is out of range.
pub fn dict_word(attr_name: &str, id: u64) -> Option<String> {
    let i = id as usize;
    let from = |words: &[&str]| words.get(i).map(|w| w.to_string());
    match attr_name {
        "p_mfgr" => (i < 5).then(|| format!("Manufacturer#{}", i + 1)),
        "p_brand" => (i < 25).then(|| format!("Brand#{}{}", i / 5 + 1, i % 5 + 1)),
        "p_type" => (i < 150).then(|| {
            format!(
                "{} {} {}",
                TYPE_S1[i / 25],
                TYPE_S2[(i / 5) % 5],
                TYPE_S3[i % 5]
            )
        }),
        "p_container" => (i < 40)
            .then(|| format!("{} {}", CONTAINER_S1[i / 8], CONTAINER_S2[i % 8])),
        "c_mktsegment" => from(&SEGMENTS),
        "o_orderstatus" => from(&ORDERSTATUS),
        "o_orderpriority" => from(&PRIORITIES),
        "l_returnflag" => from(&RETURNFLAGS),
        "l_linestatus" => from(&LINESTATUS),
        "l_shipinstruct" => from(&INSTRUCTIONS),
        "l_shipmode" => from(&SHIPMODES),
        // phone country codes are stored as the literal code (10 + nation)
        "s_phone_cc" | "c_phone_cc" => Some(id.to_string()),
        _ => None,
    }
}

/// Last order date in the spec data (1998-08-02) and related bounds.
pub fn max_orderdate() -> u64 {
    date(1998, 8, 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_counts_match_table1_at_sf1000() {
        assert_eq!(RelId::Part.records_at_sf(1000.0), 200_000_000);
        assert_eq!(RelId::Supplier.records_at_sf(1000.0), 10_000_000);
        assert_eq!(RelId::Partsupp.records_at_sf(1000.0), 800_000_000);
        assert_eq!(RelId::Customer.records_at_sf(1000.0), 150_000_000);
        assert_eq!(RelId::Orders.records_at_sf(1000.0), 1_500_000_000);
        assert_eq!(RelId::Lineitem.records_at_sf(1000.0), 6_000_000_000);
        assert_eq!(RelId::Nation.records_at_sf(1000.0), 25);
    }

    #[test]
    fn row_bits_fit_crossbar_and_match_paper_scale() {
        // paper Table 1: 124 / 99 / 80 / 106 / 133 / 191 bits. Our compact
        // encodings land within ~35% (documented in EXPERIMENTS.md); all
        // must fit a 512-column crossbar row with computation headroom.
        let paper = [
            (RelId::Part, 124),
            (RelId::Supplier, 99),
            (RelId::Partsupp, 80),
            (RelId::Customer, 106),
            (RelId::Orders, 133),
            (RelId::Lineitem, 191),
        ];
        for (rel, want) in paper {
            let got = row_bits(rel);
            assert!(got < 512 / 2, "{:?} too wide: {got}", rel);
            let ratio = got as f64 / want as f64;
            assert!(
                (0.6..=1.4).contains(&ratio),
                "{:?}: got {got}, paper {want}",
                rel
            );
        }
    }

    #[test]
    fn date_arithmetic() {
        assert_eq!(date(1992, 1, 1), 0);
        assert_eq!(date(1992, 1, 2), 1);
        assert_eq!(date(1993, 1, 1), 366); // 1992 is a leap year
        assert_eq!(date(1998, 12, 1) - 90, date(1998, 9, 2)); // Q1 bound
        assert!(max_orderdate() < (1 << 12));
    }

    #[test]
    fn date_ymd_inverts_date_over_the_whole_domain() {
        // every encodable day (12-bit field) round-trips
        for days in 0u64..(1 << 12) {
            let (y, m, d) = date_ymd(days);
            assert_eq!(date(y, m, d), days, "{y}-{m}-{d}");
        }
        assert_eq!(date_ymd(0), (1992, 1, 1));
        assert_eq!(date_ymd(date(1998, 9, 2)), (1998, 9, 2));
    }

    #[test]
    fn dict_word_inverts_the_id_encoders() {
        assert_eq!(dict_word("p_brand", brand_id("Brand#32")).unwrap(), "Brand#32");
        assert_eq!(
            dict_word("p_type", type_id_of("ECONOMY ANODIZED STEEL")).unwrap(),
            "ECONOMY ANODIZED STEEL"
        );
        assert_eq!(
            dict_word("p_container", container_id("LG DRUM")).unwrap(),
            "LG DRUM"
        );
        assert_eq!(dict_word("c_mktsegment", segment_id("BUILDING")).unwrap(), "BUILDING");
        assert_eq!(dict_word("l_shipmode", shipmode_id("RAIL")).unwrap(), "RAIL");
        assert_eq!(dict_word("l_returnflag", returnflag_id("A")).unwrap(), "A");
        assert_eq!(dict_word("l_linestatus", 0).unwrap(), "O");
        assert_eq!(dict_word("o_orderstatus", orderstatus_id("P")).unwrap(), "P");
        assert_eq!(
            dict_word("o_orderpriority", 0).unwrap(),
            "1-URGENT"
        );
        assert_eq!(dict_word("p_mfgr", 4).unwrap(), "Manufacturer#5");
        assert_eq!(dict_word("s_phone_cc", 27).unwrap(), "27");
        // out-of-vocabulary ids and unknown attributes decode to None
        assert_eq!(dict_word("p_brand", 25), None);
        assert_eq!(dict_word("l_quantity", 3), None);
    }

    #[test]
    fn type_like_expansions() {
        assert_eq!(type_ids_ending_with("BRASS").len(), 30);
        assert_eq!(type_ids_starting_with("PROMO").len(), 25);
        assert_eq!(type_ids_with_prefix2("MEDIUM", "POLISHED").len(), 5);
        assert_eq!(type_id_of("ECONOMY ANODIZED STEEL"), type_id(4, 0, 3));
        // %BRASS ids are exactly those ≡ 2 (mod 5)
        assert!(type_ids_ending_with("BRASS").iter().all(|id| id % 5 == 2));
    }

    #[test]
    fn dict_ids_in_range() {
        assert_eq!(brand_id("Brand#11"), 0);
        assert_eq!(brand_id("Brand#55"), 24);
        assert_eq!(container_id("SM CASE"), 0);
        assert_eq!(container_id("WRAP DRUM"), 39);
        assert_eq!(segment_id("BUILDING"), 1);
        assert_eq!(shipmode_id("MAIL"), 5);
        assert_eq!(nation_id("GERMANY"), 7);
    }

    #[test]
    fn regions_partition_nations() {
        let mut all: Vec<u64> = REGIONS
            .iter()
            .flat_map(|r| nations_in_region(r))
            .collect();
        all.sort();
        assert_eq!(all, (0..25).collect::<Vec<_>>());
        assert_eq!(nations_in_region("EUROPE").len(), 5);
        assert!(nations_in_region("EUROPE").contains(&nation_id("GERMANY")));
    }

    #[test]
    fn attr_lookup_and_widths() {
        let a = attr(RelId::Lineitem, "l_shipdate").unwrap();
        assert_eq!(a.bits, 12);
        assert!(attr(RelId::Lineitem, "nope").is_none());
        // every attribute fits its declared width domain for dates/dicts
        assert!(attrs(RelId::Lineitem).iter().all(|a| a.bits <= 64));
        assert_eq!(attr_index(RelId::Lineitem, "l_orderkey"), Some(0));
    }
}
