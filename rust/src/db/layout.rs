//! Relation → PIM memory layout (paper §4.1, §5.1, Table 1).
//!
//! Each record occupies one crossbar row; attributes are aligned across
//! rows in consecutive cells; a VALID column marks occupied rows. The
//! layout carries two views:
//!
//!  * **report view** (SF = `report_sf`, paper: 1000): page counts, row
//!    bits, utilization — Table 1, and the volumes the timing model uses;
//!  * **sim view** (SF = `sim_sf`): the crossbars actually materialized
//!    from the generated data, distributed over the report pages the way
//!    the paper emulates 1 GB pages with small ones (§5.4).

use super::schema::{self, Attr, RelId};
use crate::config::SystemConfig;
use crate::mem::vm::{CapacityError, HugePage, PageAllocator};

/// Why laying the database out over the PIM modules failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayoutError {
    /// A relation's record (data bits + VALID) is wider than the crossbar.
    RowTooWide {
        /// The relation whose record does not fit.
        rel: RelId,
        /// Bits one record occupies (including the VALID column).
        row_bits: usize,
        /// Columns a crossbar row offers.
        xbar_cols: usize,
    },
    /// The page allocator ran out of PIM capacity.
    Capacity(CapacityError),
}

impl std::fmt::Display for LayoutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LayoutError::RowTooWide {
                rel,
                row_bits,
                xbar_cols,
            } => write!(
                f,
                "{rel:?} row ({row_bits}b) exceeds crossbar ({xbar_cols} cols)"
            ),
            LayoutError::Capacity(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for LayoutError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LayoutError::Capacity(e) => Some(e),
            LayoutError::RowTooWide { .. } => None,
        }
    }
}

impl From<CapacityError> for LayoutError {
    fn from(e: CapacityError) -> LayoutError {
        LayoutError::Capacity(e)
    }
}

/// Column placement of one attribute inside the crossbar row.
#[derive(Clone, Copy, Debug)]
pub struct AttrSlot {
    /// The attribute placed in this slot.
    pub attr: Attr,
    /// First bit column.
    pub start: usize,
}

/// Layout of one relation.
#[derive(Clone, Debug)]
pub struct RelationLayout {
    /// The relation this layout describes.
    pub rel: RelId,
    /// Column slots in schema order.
    pub slots: Vec<AttrSlot>,
    /// VALID bit column.
    pub valid_col: usize,
    /// Bits of data per record (incl. valid).
    pub row_bits: usize,
    /// First column available for intermediate results.
    pub compute_base: usize,
    /// Records at the report scale factor.
    pub records_report: u64,
    /// Huge-pages at the report scale factor (Table 1 "# of PIM Pages").
    pub pages_report: u64,
    /// Allocated pages (report geometry, placed on modules/banks).
    pub pages: Vec<HugePage>,
    /// Records materialized in the simulation.
    pub records_sim: u64,
    /// Crossbars materialized in the simulation.
    pub xbars_sim: u64,
}

impl RelationLayout {
    /// Column slot of `attr_name`, if the attribute exists.
    pub fn slot(&self, attr_name: &str) -> Option<AttrSlot> {
        self.slots
            .iter()
            .find(|s| s.attr.name == attr_name)
            .copied()
    }

    /// Free columns for intermediates (paper: most unoccupied row space is
    /// usable for computation).
    pub fn compute_cols(&self, cfg: &SystemConfig) -> usize {
        cfg.xbar_cols - self.compute_base
    }

    /// Memory utilization at the report SF (Table 1): data bits over
    /// allocated page bits.
    pub fn utilization(&self, cfg: &SystemConfig) -> f64 {
        let data_bits = self.records_report as f64 * self.row_bits as f64;
        let page_bits = self.pages_report as f64 * cfg.page_bytes as f64 * 8.0;
        data_bits / page_bits
    }

    /// Sim crossbars that live on report page `p` (the sim data is spread
    /// over the report pages round-robin; page p gets xbars p, p+P, ...).
    pub fn sim_xbars_on_page(&self, p: usize) -> u64 {
        let pages = self.pages_report.max(1);
        let full = self.xbars_sim / pages;
        let extra = (self.xbars_sim % pages > p as u64) as u64;
        full + extra
    }

    /// Rows occupied in sim crossbar `x` (the last crossbar is partial).
    pub fn rows_in_xbar(&self, x: u64, cfg: &SystemConfig) -> usize {
        let rows = cfg.xbar_rows as u64;
        if x + 1 < self.xbars_sim {
            rows as usize
        } else {
            (self.records_sim - x * rows) as usize
        }
    }
}

/// Compute layouts for all PIM relations and allocate their pages.
pub struct DbLayout {
    /// Per-relation layouts, in [`schema::PIM_RELATIONS`] order.
    pub relations: Vec<RelationLayout>,
    /// Total report-view pages across all relations.
    pub total_pages: u64,
    /// Pages in the fullest PIM module (power bound input).
    pub max_pages_in_module: u64,
}

impl DbLayout {
    /// Lay out every PIM relation and allocate its pages.
    pub fn build(
        cfg: &SystemConfig,
        sim_records: &dyn Fn(RelId) -> u64,
    ) -> Result<DbLayout, LayoutError> {
        let mut alloc = PageAllocator::new(cfg);
        let mut relations = Vec::new();
        for rel in schema::PIM_RELATIONS {
            let mut slots = Vec::new();
            let mut col = 0usize;
            for &attr in schema::attrs(rel) {
                slots.push(AttrSlot { attr, start: col });
                col += attr.bits;
            }
            let valid_col = col;
            let row_bits = col + 1;
            if row_bits > cfg.xbar_cols {
                return Err(LayoutError::RowTooWide {
                    rel,
                    row_bits,
                    xbar_cols: cfg.xbar_cols,
                });
            }
            let records_report = rel.records_at_sf(cfg.report_sf);
            let pages_report = records_report.div_ceil(cfg.records_per_page());
            let pages = alloc.allocate(pages_report as usize)?;
            let records_sim = sim_records(rel);
            let xbars_sim = records_sim.div_ceil(cfg.xbar_rows as u64).max(1);
            relations.push(RelationLayout {
                rel,
                slots,
                valid_col,
                row_bits,
                compute_base: row_bits,
                records_report,
                pages_report,
                pages,
                records_sim,
                xbars_sim,
            });
        }
        Ok(DbLayout {
            total_pages: alloc.pages_allocated() as u64,
            max_pages_in_module: alloc.max_pages_in_module(),
            relations,
        })
    }

    /// One relation's layout by id (panics for non-PIM relations).
    pub fn rel(&self, rel: RelId) -> &RelationLayout {
        self.relations
            .iter()
            .find(|r| r.rel == rel)
            .expect("relation not in PIM layout")
    }

    /// Overall utilization (Table 1 "Total" row).
    pub fn total_utilization(&self, cfg: &SystemConfig) -> f64 {
        let data: f64 = self
            .relations
            .iter()
            .map(|r| r.records_report as f64 * r.row_bits as f64)
            .sum();
        let pages: f64 = self
            .relations
            .iter()
            .map(|r| r.pages_report as f64 * cfg.page_bytes as f64 * 8.0)
            .sum();
        data / pages
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> (SystemConfig, DbLayout) {
        let cfg = SystemConfig::default();
        let l = DbLayout::build(&cfg, &|rel| rel.records_at_sf(0.01)).unwrap();
        (cfg, l)
    }

    #[test]
    fn page_counts_match_table1() {
        let (_, l) = layout();
        // paper Table 1 at SF=1000: 12/1/48/9/90/358, total 518
        assert_eq!(l.rel(RelId::Part).pages_report, 12);
        assert_eq!(l.rel(RelId::Supplier).pages_report, 1);
        assert_eq!(l.rel(RelId::Partsupp).pages_report, 48);
        assert_eq!(l.rel(RelId::Customer).pages_report, 9);
        assert_eq!(l.rel(RelId::Orders).pages_report, 90);
        assert_eq!(l.rel(RelId::Lineitem).pages_report, 358);
        assert_eq!(l.total_pages, 518);
    }

    #[test]
    fn utilization_in_paper_band() {
        let (cfg, l) = layout();
        // paper total: 32.6% with wider encodings; ours is lower-bounded by
        // the same page math — just assert the sane band and ordering
        let total = l.total_utilization(&cfg);
        assert!((0.1..0.5).contains(&total), "total {total}");
        // LINEITEM (widest rows, fullest pages) has the highest utilization
        let li = l.rel(RelId::Lineitem).utilization(&cfg);
        for r in &l.relations {
            assert!(li >= r.utilization(&cfg) - 1e-9, "{:?}", r.rel);
        }
    }

    #[test]
    fn slots_are_disjoint_and_ordered() {
        let (_, l) = layout();
        for r in &l.relations {
            let mut prev_end = 0;
            for s in &r.slots {
                assert!(s.start >= prev_end);
                prev_end = s.start + s.attr.bits;
            }
            assert_eq!(r.valid_col, prev_end);
            assert_eq!(r.row_bits, prev_end + 1);
        }
    }

    #[test]
    fn compute_area_left_for_intermediates() {
        let (cfg, l) = layout();
        for r in &l.relations {
            // the widest instruction needs ~n+15 intermediate cells; all
            // relations must leave >= 80 columns
            assert!(r.compute_cols(&cfg) >= 80, "{:?}", r.rel);
        }
    }

    #[test]
    fn sim_xbars_distribute_over_report_pages() {
        let (_, l) = layout();
        let li = l.rel(RelId::Lineitem);
        let total: u64 = (0..li.pages_report as usize)
            .map(|p| li.sim_xbars_on_page(p))
            .sum();
        assert_eq!(total, li.xbars_sim);
    }

    #[test]
    fn last_crossbar_partial_rows() {
        let (cfg, l) = layout();
        let s = l.rel(RelId::Supplier);
        // 100 records at SF 0.01 -> 1 crossbar with 100 rows
        assert_eq!(s.records_sim, 100);
        assert_eq!(s.xbars_sim, 1);
        assert_eq!(s.rows_in_xbar(0, &cfg), 100);
    }

    #[test]
    fn capacity_fits_paper_system() {
        let (cfg, l) = layout();
        // 518 pages of 1 GB fit in 8 x 128 GB modules
        assert!(l.total_pages * cfg.page_bytes <= cfg.pim_capacity());
        assert!(l.max_pages_in_module <= cfg.module_capacity / cfg.page_bytes);
    }
}
