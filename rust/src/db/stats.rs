//! Per-crossbar zone-map statistics for statistics-driven shard pruning.
//!
//! Every query used to execute its full mask program over every crossbar
//! of a relation, even when a selective filter provably selects nothing
//! on most of them. This module computes, per crossbar and per attribute
//! slot, a **zone map** over the *encoded* bit-plane value — min/max,
//! the live-row count, and (for narrow dictionary columns) a distinct-id
//! presence bitmap. The pruning pass in [`crate::query::opt::prune`]
//! consults these zones to prove a predicate's mask is all-zero on a
//! crossbar, letting the executor skip it entirely.
//!
//! Lifecycle: stats are built from the crossbar states at load
//! ([`RelStats::build`]) and maintained incrementally by the
//! group-commit leader ([`RelStats::update`] recomputes only crossbars
//! whose planes actually changed). They are published epoch-tagged
//! alongside the relation's `RelVersion`, so a pinned snapshot reader
//! always sees stats consistent with its planes; recovery rebuilds them
//! from the recovered states through the same `build` path (stats are
//! derived state and are never checkpointed).
//!
//! The zone computation itself reuses the engine's plane-narrowing
//! ReduceMin/ReduceMax idiom: walk the bit-planes MSB-first, keeping the
//! candidate row set that can still attain the extremum. The whole
//! decision procedure is mirrored line-by-line in `python/statsmirror.py`
//! and pinned cross-language by [`RelStats::digest`].

use crate::db::layout::RelationLayout;
use crate::db::schema::Encoding;
use crate::exec::engine::XbarState;
use crate::pim::isa::ColRange;
use crate::util::bits::{is_zero_words, popcount_words, WORDS, WORD_BITS};

/// Widest dictionary column (in bits) that gets a distinct-id presence
/// bitmap: the 64 bits of one `u64` cover every id of a `<= 6`-bit
/// vocabulary.
pub const DICT_BITMAP_MAX_BITS: usize = 6;

/// Zone map of one attribute slot on one crossbar, over live rows only.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ColZone {
    /// Smallest encoded value among live rows (`u64::MAX` when the
    /// crossbar has no live rows — the empty-zone sentinel).
    pub min: u64,
    /// Largest encoded value among live rows (`0` when empty).
    pub max: u64,
    /// Distinct-id presence bitmap for dictionary columns of at most
    /// [`DICT_BITMAP_MAX_BITS`] bits: bit `v` is set iff some live row
    /// holds id `v`. `None` for non-dict or wide columns.
    pub dict: Option<u64>,
}

impl ColZone {
    /// The sentinel zone of a crossbar with no live rows: an empty range
    /// (`min > max`) that every range predicate is disjoint from.
    pub fn empty(dict_bitmap: bool) -> ColZone {
        ColZone {
            min: u64::MAX,
            max: 0,
            dict: if dict_bitmap { Some(0) } else { None },
        }
    }
}

/// Zone maps of one crossbar: live-row count plus one [`ColZone`] per
/// attribute slot, in `layout.slots` order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct XbarStats {
    /// Rows with the VALID bit set.
    pub live_rows: u64,
    /// Per-slot zones, parallel to `RelationLayout::slots`.
    pub zones: Vec<ColZone>,
}

/// Zone-map statistics of one relation version: one [`XbarStats`] per
/// materialized crossbar, in crossbar order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RelStats {
    /// Per-crossbar stats, parallel to the version's `Vec<XbarState>`.
    pub xbars: Vec<XbarStats>,
}

/// Whether an attribute slot gets a distinct-id presence bitmap.
fn wants_dict_bitmap(enc: Encoding, bits: usize) -> bool {
    enc == Encoding::Dict && bits <= DICT_BITMAP_MAX_BITS
}

fn and_words(a: &[u64; WORDS], b: &[u64; WORDS]) -> [u64; WORDS] {
    let mut r = [0u64; WORDS];
    for i in 0..WORDS {
        r[i] = a[i] & b[i];
    }
    r
}

fn andnot_words(a: &[u64; WORDS], b: &[u64; WORDS]) -> [u64; WORDS] {
    let mut r = [0u64; WORDS];
    for i in 0..WORDS {
        r[i] = a[i] & !b[i];
    }
    r
}

/// Zone of one slot on one crossbar, given the live-row plane.
///
/// Min/max walk the slot's bit-planes MSB-first keeping the candidate
/// set of rows that can still attain the extremum — the same narrowing
/// the engine's ReduceMin/ReduceMax kernels perform, so the zone is
/// exact over live rows (not an approximation).
fn col_zone(st: &XbarState, start: usize, bits: usize, dict_bitmap: bool, live: &[u64; WORDS]) -> ColZone {
    if is_zero_words(live) {
        return ColZone::empty(dict_bitmap);
    }
    let mut cand = *live;
    let mut max = 0u64;
    for j in (0..bits).rev() {
        let narrowed = and_words(&cand, &st.planes[start + j]);
        if !is_zero_words(&narrowed) {
            cand = narrowed;
            max |= 1 << j;
        }
    }
    let mut cand = *live;
    let mut min = 0u64;
    for j in (0..bits).rev() {
        let narrowed = andnot_words(&cand, &st.planes[start + j]);
        if !is_zero_words(&narrowed) {
            cand = narrowed;
        } else {
            min |= 1 << j;
        }
    }
    let dict = if dict_bitmap {
        let mut bm = 0u64;
        for (w, &word) in live.iter().enumerate() {
            let mut rest = word;
            while rest != 0 {
                let row = w * WORD_BITS + rest.trailing_zeros() as usize;
                bm |= 1 << st.value_at(row, ColRange::new(start, bits));
                rest &= rest - 1;
            }
        }
        Some(bm)
    } else {
        None
    };
    ColZone { min, max, dict }
}

/// Stats of one crossbar under `layout`.
fn xbar_stats(st: &XbarState, layout: &RelationLayout) -> XbarStats {
    let live = st.planes[layout.valid_col];
    XbarStats {
        live_rows: popcount_words(&live),
        zones: layout
            .slots
            .iter()
            .map(|s| {
                col_zone(
                    st,
                    s.start,
                    s.attr.bits,
                    wants_dict_bitmap(s.attr.enc, s.attr.bits),
                    &live,
                )
            })
            .collect(),
    }
}

impl RelStats {
    /// Build zone maps for every crossbar of a relation version — the
    /// load-time (and recovery-time) path.
    pub fn build(states: &[XbarState], layout: &RelationLayout) -> RelStats {
        RelStats {
            xbars: states.iter().map(|st| xbar_stats(st, layout)).collect(),
        }
    }

    /// Incremental rebuild after a group-committed DML batch: crossbars
    /// whose planes are unchanged keep their previous stats; mutated or
    /// newly appended crossbars are recomputed. `old_states` are the
    /// pre-batch planes of the version `prev` was built from.
    pub fn update(
        prev: &RelStats,
        old_states: &[XbarState],
        new_states: &[XbarState],
        layout: &RelationLayout,
    ) -> RelStats {
        debug_assert_eq!(prev.xbars.len(), old_states.len());
        RelStats {
            xbars: new_states
                .iter()
                .enumerate()
                .map(|(x, st)| {
                    if x < old_states.len() && old_states[x].planes == st.planes {
                        prev.xbars[x].clone()
                    } else {
                        xbar_stats(st, layout)
                    }
                })
                .collect(),
        }
    }

    /// Canonical FNV-1a digest of the stats, for the cross-language
    /// golden pin against `python/statsmirror.py`. Serialization:
    /// little-endian u64s — crossbar count, then per crossbar the
    /// live-row count followed by each zone's `min`, `max`, and a
    /// `(has_dict, bitmap)` pair.
    pub fn digest(&self) -> u64 {
        let mut buf: Vec<u8> = Vec::new();
        let mut put = |v: u64| buf.extend_from_slice(&v.to_le_bytes());
        put(self.xbars.len() as u64);
        for x in &self.xbars {
            put(x.live_rows);
            for z in &x.zones {
                put(z.min);
                put(z.max);
                put(z.dict.is_some() as u64);
                put(z.dict.unwrap_or(0));
            }
        }
        crate::api::cache::fnv1a(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::db::layout::DbLayout;
    use crate::db::schema::RelId;
    use crate::util::rng::Rng;

    fn layout() -> RelationLayout {
        let cfg = SystemConfig::default();
        DbLayout::build(&cfg, &|rel| rel.records_at_sf(0.002))
            .unwrap()
            .rel(RelId::Supplier)
            .clone()
    }

    /// Deterministic states: `n` crossbars of the SUPPLIER layout with
    /// Rng-driven values and liveness. Shared with the golden-digest pin
    /// (mirrored by python/statsmirror.py's `golden_states`).
    fn golden_states(layout: &RelationLayout, n: usize, seed: u64) -> Vec<XbarState> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut st = XbarState::new(layout.compute_base + 8);
                for row in 0..200 {
                    let live = rng.next_u64() % 4 != 0;
                    for s in &layout.slots {
                        let v = rng.next_u64() & ((1u64 << s.attr.bits) - 1);
                        if live {
                            st.write_value(row, ColRange::new(s.start, s.attr.bits), v);
                        }
                    }
                    st.write_value(row, ColRange::new(layout.valid_col, 1), live as u64);
                }
                st
            })
            .collect()
    }

    #[test]
    fn zones_match_scalar_scan() {
        let layout = layout();
        let states = golden_states(&layout, 3, 7);
        let stats = RelStats::build(&states, &layout);
        for (x, st) in states.iter().enumerate() {
            let live: Vec<usize> = (0..crate::util::bits::XBAR_ROWS)
                .filter(|&r| st.value_at(r, ColRange::new(layout.valid_col, 1)) == 1)
                .collect();
            assert_eq!(stats.xbars[x].live_rows, live.len() as u64);
            for (i, s) in layout.slots.iter().enumerate() {
                let r = ColRange::new(s.start, s.attr.bits);
                let vals: Vec<u64> = live.iter().map(|&row| st.value_at(row, r)).collect();
                let z = &stats.xbars[x].zones[i];
                if vals.is_empty() {
                    assert_eq!((z.min, z.max), (u64::MAX, 0));
                } else {
                    assert_eq!(z.min, *vals.iter().min().unwrap(), "{} min", s.attr.name);
                    assert_eq!(z.max, *vals.iter().max().unwrap(), "{} max", s.attr.name);
                }
                match z.dict {
                    Some(bm) => {
                        assert!(wants_dict_bitmap(s.attr.enc, s.attr.bits));
                        let want = vals.iter().fold(0u64, |a, &v| a | (1 << v));
                        assert_eq!(bm, want, "{} bitmap", s.attr.name);
                    }
                    None => assert!(!wants_dict_bitmap(s.attr.enc, s.attr.bits)),
                }
            }
        }
    }

    #[test]
    fn empty_crossbar_gets_sentinel_zones() {
        let layout = layout();
        let st = XbarState::new(layout.compute_base + 8);
        let stats = RelStats::build(&[st], &layout);
        assert_eq!(stats.xbars[0].live_rows, 0);
        for z in &stats.xbars[0].zones {
            assert!(z.min > z.max);
            assert_eq!(z.dict.unwrap_or(0), 0);
        }
    }

    #[test]
    fn incremental_update_equals_full_rebuild() {
        let layout = layout();
        let old = golden_states(&layout, 4, 21);
        let prev = RelStats::build(&old, &layout);
        // mutate crossbar 2, append crossbar 4
        let mut new = old.clone();
        new[2].write_value(5, ColRange::new(layout.slots[0].start, layout.slots[0].attr.bits), 3);
        new[2].write_value(5, ColRange::new(layout.valid_col, 1), 1);
        new.extend(golden_states(&layout, 1, 99));
        let inc = RelStats::update(&prev, &old, &new, &layout);
        let full = RelStats::build(&new, &layout);
        assert_eq!(inc, full);
        // unchanged crossbars kept their exact prior stats
        assert_eq!(inc.xbars[0], prev.xbars[0]);
        assert_eq!(inc.xbars[3], prev.xbars[3]);
    }

    #[test]
    fn golden_digest_pinned_cross_language() {
        // Mirrored by python/statsmirror.py::test_golden_digest — the two
        // implementations must serialize and hash identically.
        let layout = layout();
        let stats = RelStats::build(&golden_states(&layout, 3, 0xDB), &layout);
        assert_eq!(stats.digest(), 0x06BE_552B_21FA_62A7, "stats golden digest drifted");
    }
}
