//! Endurance-aware free-row map: row liveness + per-row wear counters for
//! one relation's PIM copy.
//!
//! The mutable-relation model (follow-up work to the paper: row-granular
//! valid-bit mutation for bulk-bitwise PIM, arXiv:2302.01675 /
//! arXiv:2307.00658) needs two pieces of bookkeeping the read-only engine
//! never had:
//!
//! * **liveness** — which crossbar rows hold a live record (the VALID
//!   column in the arrays; this map is its host-side shadow, so INSERT
//!   can find a free row without scanning the arrays), and
//! * **wear** — cumulative cell writes per row, fed by the same
//!   per-instruction write profiles the endurance report uses
//!   ([`crate::pim::endurance`], paper §6.4). INSERT allocates the free
//!   row minimizing `(wear, row index)` — wear-leveling row placement so
//!   ingest traffic spreads over the least-written rows instead of
//!   hammering the lowest free index.
//!
//! The allocation policy is fully deterministic and mirrored line by line
//! in `python/dmlmirror.py` (the no-Rust-toolchain validation workflow):
//! the scripted scenario of [`golden_alloc_digest`] is pinned to the same
//! constant in both languages, so a one-sided policy change breaks
//! exactly one of the two suites.

use std::collections::BTreeSet;

use crate::util::bits::EpochMask;

/// Row liveness + wear map of one relation's materialized crossbars.
///
/// Rows are global sim-row indices (`crossbar * rows_per_xbar + row`).
/// Column-wise instruction wear is identical on every crossbar of a
/// relation (they execute the same stream in lockstep), so a
/// `rows_per_xbar`-long profile charges the whole map.
#[derive(Clone, Debug)]
pub struct FreeRowMap {
    rows_per_xbar: usize,
    live: Vec<bool>,
    /// Monotonically nondecreasing cell-write counters, one per row.
    wear: Vec<u64>,
    /// Free rows ordered by `(wear, row)` — the allocation policy.
    free: BTreeSet<(u64, usize)>,
}

impl FreeRowMap {
    /// A map of `capacity` rows with the first `initial_live` live (the
    /// loaded records) and the rest free. `rows_per_xbar` is the crossbar
    /// row count of the layout the map shadows.
    pub fn new(capacity: usize, initial_live: usize, rows_per_xbar: usize) -> FreeRowMap {
        assert!(initial_live <= capacity, "more live rows than capacity");
        FreeRowMap::from_flags(
            &(0..capacity).map(|i| i < initial_live).collect::<Vec<_>>(),
            capacity,
            rows_per_xbar,
        )
    }

    /// A map whose liveness comes from per-slot flags — the shadow of a
    /// *mutated* load image ([`crate::db::dbgen::Relation::live`]), where
    /// dead slots sit between live ones. Slots beyond `flags.len()` (the
    /// unoccupied tail of the last crossbar) are free. The allocation
    /// policy is unchanged; this is bookkeeping-only, so the Python
    /// mirror pins [`FreeRowMap::new`]'s prefix form.
    pub fn from_flags(flags: &[bool], capacity: usize, rows_per_xbar: usize) -> FreeRowMap {
        assert!(flags.len() <= capacity, "more flags than capacity");
        assert!(rows_per_xbar >= 1);
        let live: Vec<bool> = (0..capacity)
            .map(|i| flags.get(i).copied().unwrap_or(false))
            .collect();
        FreeRowMap {
            rows_per_xbar,
            free: live
                .iter()
                .enumerate()
                .filter(|(_, &l)| !l)
                .map(|(i, _)| (0, i))
                .collect(),
            live,
            wear: vec![0; capacity],
        }
    }

    /// Rebuild a map from persisted liveness + wear vectors (checkpoint
    /// recovery, [`crate::storage`]). The free set is reconstructed from
    /// the dead rows ordered by `(wear, row)` — exactly the state
    /// [`FreeRowMap::charge_profile`] maintains — so allocation order
    /// after recovery is bit-identical to the never-closed map.
    pub fn restore(live: Vec<bool>, wear: Vec<u64>, rows_per_xbar: usize) -> FreeRowMap {
        assert_eq!(live.len(), wear.len(), "liveness/wear length mismatch");
        assert!(rows_per_xbar >= 1);
        FreeRowMap {
            rows_per_xbar,
            free: live
                .iter()
                .enumerate()
                .filter(|(_, &l)| !l)
                .map(|(i, _)| (wear[i], i))
                .collect(),
            live,
            wear,
        }
    }

    /// Crossbar row count of the layout this map shadows.
    pub fn rows_per_xbar(&self) -> usize {
        self.rows_per_xbar
    }

    /// Total rows tracked (live + free).
    pub fn capacity(&self) -> usize {
        self.live.len()
    }

    /// Live rows.
    pub fn live_count(&self) -> usize {
        self.live.len() - self.free.len()
    }

    /// Whether `row` holds a live record.
    pub fn is_live(&self, row: usize) -> bool {
        self.live[row]
    }

    /// Cumulative cell writes charged to `row`.
    pub fn row_wear(&self, row: usize) -> u64 {
        self.wear[row]
    }

    /// Sum of all per-row wear counters.
    pub fn total_wear(&self) -> u64 {
        self.wear.iter().fold(0u64, |a, &w| a.wrapping_add(w))
    }

    /// Take the least-worn free row (ties break to the lowest index) and
    /// mark it live; `None` when every row is live.
    pub fn alloc(&mut self) -> Option<usize> {
        let entry = *self.free.iter().next()?;
        self.free.remove(&entry);
        let row = entry.1;
        self.live[row] = true;
        Some(row)
    }

    /// Mark a live row free again (DELETE), keeping its wear history.
    pub fn release(&mut self, row: usize) {
        debug_assert!(self.live[row], "double free of row {row}");
        self.live[row] = false;
        self.free.insert((self.wear[row], row));
    }

    /// Append `rows` fresh free rows (a newly materialized crossbar).
    pub fn grow(&mut self, rows: usize) {
        let base = self.live.len();
        self.live.resize(base + rows, false);
        self.wear.resize(base + rows, 0);
        for i in 0..rows {
            self.free.insert((0, base + i));
        }
    }

    /// Add `writes` cell writes to one row (an INSERT row write).
    pub fn charge_row(&mut self, row: usize, writes: u64) {
        if !self.live[row] {
            self.free.remove(&(self.wear[row], row));
            self.free
                .insert((self.wear[row].wrapping_add(writes), row));
        }
        self.wear[row] = self.wear[row].wrapping_add(writes);
    }

    /// Charge a per-crossbar write profile to every row: `totals[r]` is
    /// the cell writes row `r` of *each* crossbar received (all crossbars
    /// of a relation execute the same instruction stream in lockstep).
    pub fn charge_profile(&mut self, totals: &[u64]) {
        debug_assert_eq!(totals.len(), self.rows_per_xbar);
        let mut changed = false;
        for (i, w) in self.wear.iter_mut().enumerate() {
            let add = totals[i % self.rows_per_xbar];
            if add != 0 {
                *w = w.wrapping_add(add);
                changed = true;
            }
        }
        if changed {
            // wear of free rows moved: rebuild the ordered entries
            self.free = self
                .live
                .iter()
                .enumerate()
                .filter(|(_, &l)| !l)
                .map(|(i, _)| (self.wear[i], i))
                .collect();
        }
    }
}

/// Epoch-versioned row map: the committed [`FreeRowMap`] plus the
/// two-plane [`EpochMask`] that lets a DML batch flip row visibility
/// atomically while in-flight readers keep scanning their snapshot.
///
/// The batch discipline is *take-out / put-back*:
///
/// 1. [`EpochRowMap::begin_batch`] hands the caller an owned clone of
///    the committed map (the *pending* map). The writer mutates that
///    clone — and its private copy of the crossbar arrays — with **no
///    lock held** on this structure, so readers are never blocked by
///    batch execution.
/// 2. [`EpochRowMap::commit_batch`] takes the pending map back, syncs
///    the shadow visibility plane to it, flips the active plane, bumps
///    the epoch and installs the pending map as committed — the only
///    step that needs exclusive access, and it is O(capacity) bit
///    bookkeeping, not query work.
/// 3. [`EpochRowMap::abort_batch`] discards the shadow; the committed
///    state (including wear — an aborted batch charges no wear) is
///    untouched.
///
/// Invariant (asserted by the fuzz tests): after every commit/abort the
/// active [`EpochMask`] plane equals the committed map's liveness.
#[derive(Clone, Debug)]
pub struct EpochRowMap {
    committed: FreeRowMap,
    mask: EpochMask,
    epoch: u64,
    in_batch: bool,
}

impl EpochRowMap {
    /// Wrap a committed map at epoch 0.
    pub fn new(committed: FreeRowMap) -> EpochRowMap {
        let flags: Vec<bool> = (0..committed.capacity()).map(|i| committed.is_live(i)).collect();
        EpochRowMap {
            mask: EpochMask::from_flags(&flags, committed.capacity()),
            committed,
            epoch: 0,
            in_batch: false,
        }
    }

    /// Rebuild an epoch map from a persisted committed map and its epoch
    /// (checkpoint recovery, [`crate::storage`]). Identical to
    /// [`EpochRowMap::new`] except the batch counter resumes where the
    /// checkpointed handle left off, so WAL replay commits land on the
    /// same epoch numbers the original group-commit leader assigned.
    pub fn restore(committed: FreeRowMap, epoch: u64) -> EpochRowMap {
        let mut em = EpochRowMap::new(committed);
        em.epoch = epoch;
        em
    }

    /// Number of committed batches so far — the snapshot version tag.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Whether a batch is in flight (begun, not yet committed/aborted).
    pub fn in_batch(&self) -> bool {
        self.in_batch
    }

    /// The committed map (liveness, wear).
    pub fn committed(&self) -> &FreeRowMap {
        &self.committed
    }

    /// Committed visibility of `row` (the active epoch plane).
    pub fn is_live(&self, row: usize) -> bool {
        self.mask.get(row)
    }

    /// Committed live-row count.
    pub fn live_count(&self) -> usize {
        self.committed.live_count()
    }

    /// Charge committed wear outside a batch (reader-side endurance
    /// accounting; queries wear cells too). Not legal mid-batch — the
    /// pending clone would miss the charge.
    pub fn charge_profile(&mut self, totals: &[u64]) {
        assert!(!self.in_batch, "charge_profile during a batch");
        self.committed.charge_profile(totals);
    }

    /// Start a batch: returns an owned *pending* clone of the committed
    /// map for the writer to mutate lock-free. Panics on a nested batch.
    pub fn begin_batch(&mut self) -> FreeRowMap {
        assert!(!self.in_batch, "nested DML batch on one relation");
        self.in_batch = true;
        self.mask.begin_batch();
        self.committed.clone()
    }

    /// Publish the pending map: sync the shadow plane to its liveness,
    /// flip the active plane, bump the epoch and install it as committed.
    pub fn commit_batch(&mut self, pending: FreeRowMap) {
        assert!(self.in_batch, "commit_batch outside a batch");
        // fallible-ish bookkeeping first: grow the mask to the pending
        // capacity (INSERT may have appended crossbars), then sync.
        if pending.capacity() > self.mask.capacity() {
            self.mask.grow(pending.capacity() - self.mask.capacity());
        }
        for row in 0..pending.capacity() {
            self.mask.set_pending(row, pending.is_live(row));
        }
        self.mask.commit_batch();
        self.committed = pending;
        self.epoch += 1;
        self.in_batch = false;
    }

    /// Discard the batch; committed state (and wear) is untouched.
    pub fn abort_batch(&mut self) {
        assert!(self.in_batch, "abort_batch outside a batch");
        self.mask.abort_batch();
        self.in_batch = false;
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a_fold(mut state: u64, value: u64) -> u64 {
    for byte in value.to_le_bytes() {
        state = (state ^ byte as u64).wrapping_mul(FNV_PRIME);
    }
    state
}

/// Cross-language golden pin: `python/dmlmirror.py` runs the identical
/// scripted alloc/free/charge scenario and pins the same constant
/// (`GOLDEN_ALLOC_DIGEST`). The digest folds every operation *and* every
/// allocator answer, so it pins the complete allocation order — the
/// wear-leveling policy — not just the final state.
pub fn golden_alloc_digest() -> u64 {
    let mut fm = FreeRowMap::new(64, 40, 16);
    let mut state = FNV_OFFSET;
    let mut x: u64 = 42;
    for _ in 0..200 {
        x = x
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let op = x % 4;
        let arg = ((x >> 8) % 64) as usize;
        state = fnv1a_fold(state, op);
        match op {
            0 => {
                let row = fm.alloc();
                state = fnv1a_fold(state, row.map(|r| r as u64).unwrap_or(0xFFFF));
            }
            1 => {
                // free the first live row at/after arg (wrapping)
                let row = (0..fm.capacity())
                    .map(|k| (arg + k) % fm.capacity())
                    .find(|&cand| fm.is_live(cand));
                match row {
                    None => state = fnv1a_fold(state, 0xFFFE),
                    Some(r) => {
                        fm.release(r);
                        state = fnv1a_fold(state, r as u64);
                    }
                }
            }
            2 => {
                let writes = (x >> 16) % 7 + 1;
                fm.charge_row(arg, writes);
                state = fnv1a_fold(state, arg as u64 * 1000 + writes);
            }
            _ => {
                let totals: Vec<u64> =
                    (0..16).map(|r| ((x >> 16).wrapping_add(7 * r + 3)) % 5).collect();
                fm.charge_profile(&totals);
                state = fnv1a_fold(state, totals.iter().sum());
            }
        }
    }
    state = fnv1a_fold(state, fm.live_count() as u64);
    state = fnv1a_fold(state, fm.total_wear());
    state
}

/// Cross-language golden pin for the epoch scheme: `python/epochmirror.py`
/// runs the identical scripted begin/mutate/commit/abort interleaving and
/// pins the same constant (`GOLDEN_EPOCH_DIGEST`). The digest folds every
/// operation, every allocator answer *and* committed-view probes taken
/// mid-batch, so it pins the visibility rule itself — a committed reader
/// view must never move while a batch is in flight.
pub fn golden_epoch_digest() -> u64 {
    let mut em = EpochRowMap::new(FreeRowMap::new(48, 24, 16));
    let mut state = FNV_OFFSET;
    let mut x: u64 = 7;
    let mut pending: Option<FreeRowMap> = None;
    for _ in 0..300 {
        x = x
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let op = x % 5;
        let arg = ((x >> 8) % 64) as usize;
        state = fnv1a_fold(state, op);
        match op {
            0 => match pending {
                // begin a batch (no-op fold when one is in flight)
                Some(_) => state = fnv1a_fold(state, 0),
                None => {
                    pending = Some(em.begin_batch());
                    state = fnv1a_fold(state, 1);
                }
            },
            1 => match pending.as_mut() {
                // mutate the pending clone: alloc+charge / release / grow
                None => state = fnv1a_fold(state, 2),
                Some(p) => match (x >> 16) % 3 {
                    0 => {
                        let row = p.alloc();
                        state = fnv1a_fold(state, row.map(|r| r as u64).unwrap_or(0xFFFF));
                        if let Some(r) = row {
                            p.charge_row(r, (x >> 24) % 5 + 1);
                        }
                    }
                    1 => {
                        let row = (0..p.capacity())
                            .map(|k| (arg + k) % p.capacity())
                            .find(|&cand| p.is_live(cand));
                        match row {
                            None => state = fnv1a_fold(state, 0xFFFE),
                            Some(r) => {
                                p.release(r);
                                state = fnv1a_fold(state, r as u64);
                            }
                        }
                    }
                    _ => {
                        p.grow(16);
                        state = fnv1a_fold(state, p.capacity() as u64);
                    }
                },
            },
            2 => match pending.take() {
                // commit: visibility flips, epoch bumps
                None => state = fnv1a_fold(state, 3),
                Some(p) => {
                    em.commit_batch(p);
                    state = fnv1a_fold(state, em.epoch());
                }
            },
            3 => match pending.take() {
                // abort: committed view and wear untouched
                None => state = fnv1a_fold(state, 5),
                Some(_) => {
                    em.abort_batch();
                    state = fnv1a_fold(state, 4);
                }
            },
            _ => {
                // committed-view probe (+ reader wear charge when idle) —
                // mid-batch probes must see the pre-batch state
                if pending.is_none() && (x >> 16) & 1 == 1 {
                    let totals: Vec<u64> = (0..16u64)
                        .map(|r| ((x >> 24).wrapping_add(3 * r + 1)) % 4)
                        .collect();
                    em.charge_profile(&totals);
                    state = fnv1a_fold(state, totals.iter().sum());
                }
                let r = arg % em.committed().capacity();
                state = fnv1a_fold(
                    state,
                    (em.is_live(r) as u64) | ((em.live_count() as u64) << 1),
                );
            }
        }
    }
    state = fnv1a_fold(state, em.epoch());
    state = fnv1a_fold(state, em.committed().total_wear());
    state
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn golden_alloc_digest_matches_the_python_mirror_pin() {
        // regenerate with `python3 python/dmlmirror.py`
        assert_eq!(golden_alloc_digest(), 0x9468_F2E2_165F_77A6);
    }

    #[test]
    fn alloc_prefers_least_worn_then_lowest_index() {
        let mut fm = FreeRowMap::new(8, 0, 8);
        fm.charge_row(0, 5);
        fm.charge_row(1, 2);
        fm.charge_row(3, 2);
        let order: Vec<_> = std::iter::from_fn(|| fm.alloc()).collect();
        assert_eq!(order, vec![2, 4, 5, 6, 7, 1, 3, 0]);
        assert_eq!(fm.alloc(), None);
        assert_eq!(fm.live_count(), 8);
    }

    #[test]
    fn from_flags_respects_holes_in_a_mutated_image() {
        // slots: live, dead, live, dead; tail (4..8) free
        let mut fm = FreeRowMap::from_flags(&[true, false, true, false], 8, 4);
        assert_eq!(fm.live_count(), 2);
        assert!(fm.is_live(0) && !fm.is_live(1) && fm.is_live(2));
        // the dead interior slots allocate before nothing else is worn
        assert_eq!(fm.alloc(), Some(1));
        assert_eq!(fm.alloc(), Some(3));
        assert_eq!(fm.alloc(), Some(4));
        // a live row is never handed out
        let rest: Vec<_> = std::iter::from_fn(|| fm.alloc()).collect();
        assert_eq!(rest, vec![5, 6, 7]);
    }

    #[test]
    fn restore_rebuilds_allocation_order_from_persisted_vectors() {
        // a round-trip through (live, wear) vectors — the checkpoint
        // payload — must reproduce the wear-leveling allocation order
        let mut orig = FreeRowMap::new(6, 3, 6);
        orig.charge_profile(&[4, 0, 2, 9, 1, 1]);
        orig.release(1);
        let live: Vec<bool> = (0..orig.capacity()).map(|r| orig.is_live(r)).collect();
        let wear: Vec<u64> = (0..orig.capacity()).map(|r| orig.row_wear(r)).collect();
        let mut rest = FreeRowMap::restore(live, wear, orig.rows_per_xbar());
        assert_eq!(rest.rows_per_xbar(), 6);
        assert_eq!(rest.live_count(), orig.live_count());
        let order_orig: Vec<_> = std::iter::from_fn(|| orig.alloc()).collect();
        let order_rest: Vec<_> = std::iter::from_fn(|| rest.alloc()).collect();
        assert_eq!(order_orig, order_rest);
    }

    #[test]
    fn epoch_restore_resumes_the_batch_counter() {
        let em = EpochRowMap::restore(FreeRowMap::new(8, 4, 8), 17);
        assert_eq!(em.epoch(), 17);
        assert_eq!(em.live_count(), 4);
        assert!(em.is_live(3) && !em.is_live(4));
        let mut em = em;
        let pending = em.begin_batch();
        em.commit_batch(pending);
        assert_eq!(em.epoch(), 18);
    }

    #[test]
    fn release_keeps_wear_history() {
        let mut fm = FreeRowMap::new(4, 4, 4);
        assert_eq!(fm.alloc(), None);
        fm.charge_row(1, 10);
        fm.release(1);
        fm.release(2);
        // row 2 (wear 0) beats row 1 (wear 10)
        assert_eq!(fm.alloc(), Some(2));
        assert_eq!(fm.alloc(), Some(1));
        assert_eq!(fm.row_wear(1), 10);
    }

    #[test]
    fn charge_profile_repeats_per_crossbar_and_grow_extends() {
        let mut fm = FreeRowMap::new(8, 8, 4);
        fm.charge_profile(&[1, 2, 3, 4]);
        assert_eq!(
            (0..8).map(|r| fm.row_wear(r)).collect::<Vec<_>>(),
            vec![1, 2, 3, 4, 1, 2, 3, 4]
        );
        fm.grow(4);
        assert_eq!(fm.capacity(), 12);
        assert_eq!(fm.live_count(), 8);
        // fresh rows are unworn and allocatable first
        assert_eq!(fm.alloc(), Some(8));
    }

    #[test]
    fn golden_epoch_digest_matches_the_python_mirror_pin() {
        // regenerate with `python3 python/epochmirror.py`
        assert_eq!(golden_epoch_digest(), 0x6A41_5BD4_4B7C_485C);
    }

    #[test]
    fn epoch_batch_take_out_put_back() {
        let mut em = EpochRowMap::new(FreeRowMap::new(8, 4, 8));
        assert_eq!(em.epoch(), 0);
        assert_eq!(em.live_count(), 4);

        let mut pending = em.begin_batch();
        assert!(em.in_batch());
        pending.release(1);
        let row = pending.alloc().unwrap();
        // rows 1,4..8 are all free at wear 0; ties break to lowest index
        assert_eq!(row, 1);
        pending.charge_row(row, 3);

        // committed view is frozen while the batch mutates its clone
        assert!(em.is_live(1));
        assert_eq!(em.live_count(), 4);
        assert_eq!(em.committed().row_wear(1), 0);

        em.commit_batch(pending);
        assert_eq!(em.epoch(), 1);
        assert!(em.is_live(1));
        assert_eq!(em.committed().row_wear(1), 3);
        assert!(!em.in_batch());
    }

    #[test]
    fn epoch_abort_leaves_committed_state_and_wear_untouched() {
        let mut em = EpochRowMap::new(FreeRowMap::new(8, 4, 8));
        let mut pending = em.begin_batch();
        pending.release(0);
        pending.release(1);
        pending.charge_row(2, 99);
        em.abort_batch();
        assert_eq!(em.epoch(), 0);
        assert!(em.is_live(0) && em.is_live(1));
        assert_eq!(em.committed().row_wear(2), 0);
        // a fresh batch starts from the committed state
        let p2 = em.begin_batch();
        assert!(p2.is_live(0) && p2.is_live(1));
        assert_eq!(p2.row_wear(2), 0);
    }

    #[test]
    fn epoch_commit_grows_mask_to_pending_capacity() {
        let mut em = EpochRowMap::new(FreeRowMap::new(4, 4, 4));
        let mut pending = em.begin_batch();
        assert_eq!(pending.alloc(), None);
        pending.grow(4);
        let r = pending.alloc().unwrap();
        assert_eq!(r, 4);
        em.commit_batch(pending);
        assert_eq!(em.committed().capacity(), 8);
        assert!(em.is_live(4) && !em.is_live(5));
        assert_eq!(em.live_count(), 5);
    }

    #[test]
    fn fuzz_epoch_visibility_against_two_version_oracle() {
        // the Rust half of the python fuzz suite: the two-plane mask must
        // always agree with a from-scratch (committed, Option<pending>)
        // pair of liveness vectors, with committed frozen mid-batch
        check("epoch-two-version-oracle", 120, |g| {
            let cap = g.usize(1, 32);
            let live0 = g.usize(0, cap);
            let mut em = EpochRowMap::new(FreeRowMap::new(cap, live0, 8));
            let mut committed: Vec<bool> = (0..cap).map(|i| i < live0).collect();
            let mut pending: Option<(FreeRowMap, Vec<bool>)> = None;
            let mut epoch = 0u64;
            for _ in 0..50 {
                match g.usize(0, 4) {
                    0 => {
                        if pending.is_none() {
                            let p = em.begin_batch();
                            let flags = committed.clone();
                            pending = Some((p, flags));
                        }
                    }
                    1 => {
                        if let Some((p, flags)) = pending.as_mut() {
                            match g.usize(0, 2) {
                                0 => {
                                    if let Some(r) = p.alloc() {
                                        flags[r] = true;
                                    }
                                }
                                1 => {
                                    let live: Vec<usize> = (0..flags.len())
                                        .filter(|&r| flags[r])
                                        .collect();
                                    if !live.is_empty() {
                                        let r = *g.pick(&live);
                                        p.release(r);
                                        flags[r] = false;
                                    }
                                }
                                _ => {
                                    p.grow(8);
                                    flags.resize(flags.len() + 8, false);
                                }
                            }
                        }
                    }
                    2 => {
                        if let Some((p, flags)) = pending.take() {
                            em.commit_batch(p);
                            committed = flags;
                            epoch += 1;
                        }
                    }
                    3 => {
                        if pending.take().is_some() {
                            em.abort_batch();
                        }
                    }
                    _ => {}
                }
                // committed view == oracle committed vector, always —
                // including mid-batch (snapshot stability)
                assert_eq!(em.epoch(), epoch);
                assert_eq!(em.in_batch(), pending.is_some());
                for (r, &l) in committed.iter().enumerate() {
                    assert_eq!(em.is_live(r), l, "row {r} visibility");
                    assert_eq!(em.committed().is_live(r), l);
                }
                assert_eq!(
                    em.live_count(),
                    committed.iter().filter(|&&l| l).count()
                );
            }
        });
    }

    #[test]
    fn fuzz_against_from_scratch_oracle() {
        // the Rust half of the python fuzz suite: the incremental ordered
        // set must always agree with a from-scratch min scan
        check("freerows-oracle", 150, |g| {
            let cap = g.usize(1, 40);
            let live0 = g.usize(0, cap);
            let rpx = *g.pick(&[1usize, 2, 4, 8, 16]);
            let mut fm = FreeRowMap::new(cap, live0, rpx);
            let mut live: Vec<bool> = (0..cap).map(|i| i < live0).collect();
            let mut wear: Vec<u64> = vec![0; cap];
            for _ in 0..60 {
                match g.usize(0, 4) {
                    0 => {
                        let want = (0..live.len())
                            .filter(|&r| !live[r])
                            .min_by_key(|&r| (wear[r], r));
                        let got = fm.alloc();
                        assert_eq!(got, want);
                        if let Some(r) = got {
                            live[r] = true;
                        }
                    }
                    1 => {
                        let live_rows: Vec<usize> =
                            (0..live.len()).filter(|&r| live[r]).collect();
                        if !live_rows.is_empty() {
                            let row = *g.pick(&live_rows);
                            fm.release(row);
                            live[row] = false;
                        }
                    }
                    2 => {
                        let row = g.usize(0, live.len() - 1);
                        let w = g.usize(1, 8) as u64;
                        fm.charge_row(row, w);
                        wear[row] += w;
                    }
                    3 => {
                        let totals: Vec<u64> =
                            (0..rpx).map(|_| g.usize(0, 3) as u64).collect();
                        fm.charge_profile(&totals);
                        for (i, w) in wear.iter_mut().enumerate() {
                            *w += totals[i % rpx];
                        }
                    }
                    _ => {
                        let n = rpx * g.usize(1, 2);
                        fm.grow(n);
                        live.resize(live.len() + n, false);
                        wear.resize(wear.len() + n, 0);
                    }
                }
                for (i, &l) in live.iter().enumerate() {
                    assert_eq!(fm.is_live(i), l);
                    assert_eq!(fm.row_wear(i), wear[i]);
                }
                assert_eq!(fm.live_count(), live.iter().filter(|&&l| l).count());
            }
        });
    }
}
