//! TPC-H database substrate: schema + encodings, deterministic generator,
//! the relation → crossbar layout (paper §4, §5.1), and the
//! endurance-aware free-row map backing the DML mutation path.

pub mod dbgen;
pub mod freerows;
pub mod layout;
pub mod schema;
pub mod stats;
