//! TPC-H database substrate: schema + encodings, deterministic generator,
//! and the relation → crossbar layout (paper §4, §5.1).

pub mod dbgen;
pub mod layout;
pub mod schema;
