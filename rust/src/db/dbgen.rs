//! Deterministic TPC-H data generator (dbgen substitute).
//!
//! Generates the eight TPC-H relations with the spec's §4.2 value
//! distributions (simplified where the paper's queries are insensitive),
//! already in the PIM encodings of [`schema`]: dictionary ids, day
//! offsets, offset cents. Selectivities of every predicate used by the 19
//! evaluated queries follow the spec, which is what the performance model
//! depends on.

use std::collections::BTreeMap;

use super::schema::{self, RelId};
use crate::util::rng::Rng;

/// A generated relation: encoded column store.
///
/// Since the DML refactor the store is *mutable*: every row carries a
/// liveness flag (the host-side shadow of the PIM VALID column), and the
/// mutators below let [`crate::exec::baseline::apply_dml`] mirror the
/// PIM-side mutation so differential tests stay meaningful. Scans and
/// oracles must skip dead rows ([`Relation::live`]).
#[derive(Clone, Debug)]
pub struct Relation {
    /// Which relation this is.
    pub id: RelId,
    /// Number of record slots (live + dead; grows on INSERT).
    pub records: usize,
    columns: Vec<(&'static str, Vec<u64>)>,
    valid: Vec<bool>,
}

impl Relation {
    fn new(id: RelId, records: usize) -> Self {
        Relation {
            id,
            records,
            columns: Vec::new(),
            valid: vec![true; records],
        }
    }

    fn push(&mut self, name: &'static str, col: Vec<u64>) {
        debug_assert_eq!(col.len(), self.records);
        self.columns.push((name, col));
    }

    /// The encoded column `name` (panics when absent).
    pub fn col(&self, name: &str) -> &[u64] {
        &self
            .columns
            .iter()
            .find(|(n, _)| *n == name)
            .unwrap_or_else(|| panic!("{:?} has no column {name}", self.id))
            .1
    }

    /// Whether column `name` exists.
    pub fn has_col(&self, name: &str) -> bool {
        self.columns.iter().any(|(n, _)| *n == name)
    }

    /// All column names in schema order.
    pub fn column_names(&self) -> Vec<&'static str> {
        self.columns.iter().map(|(n, _)| *n).collect()
    }

    /// Whether row `i` holds a live record (the host-side VALID shadow).
    pub fn live(&self, i: usize) -> bool {
        self.valid[i]
    }

    /// Live records (rows scans and oracles may observe).
    pub fn live_count(&self) -> usize {
        self.valid.iter().filter(|&&v| v).count()
    }

    /// Set row `i`'s liveness (DELETE clears it; re-inserting into a
    /// freed slot sets it).
    pub fn set_valid(&mut self, i: usize, live: bool) {
        self.valid[i] = live;
    }

    /// Overwrite one cell (UPDATE; the value must already be encoded).
    pub fn write(&mut self, name: &str, i: usize, v: u64) {
        let col = self
            .columns
            .iter_mut()
            .find(|(n, _)| *n == name)
            .unwrap_or_else(|| panic!("no column {name}"));
        col.1[i] = v;
    }

    /// Zero every cell of row `i` (DELETE keeps the all-zero-dead-row
    /// invariant so a mutated store reloads into PIM correctly).
    pub fn zero_row(&mut self, i: usize) {
        for (_, col) in &mut self.columns {
            col[i] = 0;
        }
    }

    /// Rebuild a relation from persisted parts (base-image recovery,
    /// [`crate::storage`]). `valid.len()` fixes the record-slot count;
    /// every column must carry exactly that many values. Column names
    /// must already be interned via [`intern_column`].
    pub fn from_parts(
        id: RelId,
        columns: Vec<(&'static str, Vec<u64>)>,
        valid: Vec<bool>,
    ) -> Relation {
        let records = valid.len();
        for (name, col) in &columns {
            assert_eq!(col.len(), records, "column {name} length mismatch");
        }
        Relation {
            id,
            records,
            columns,
            valid,
        }
    }

    /// All columns as `(name, values)` pairs in schema order (base-image
    /// serialization, [`crate::storage`]).
    pub fn columns(&self) -> impl Iterator<Item = (&'static str, &[u64])> + '_ {
        self.columns.iter().map(|(n, c)| (*n, c.as_slice()))
    }

    /// Append one live record; `values` supplies `(column, encoded
    /// value)` pairs, unlisted columns store 0. Returns the new row.
    pub fn append_row(&mut self, values: &[(&str, u64)]) -> usize {
        let row = self.records;
        for (name, col) in &mut self.columns {
            let v = values
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, v)| *v)
                .unwrap_or(0);
            col.push(v);
        }
        self.valid.push(true);
        self.records += 1;
        row
    }
}

/// The generated database.
#[derive(Clone)]
pub struct Database {
    /// Scale factor the data was generated at.
    pub sf: f64,
    /// Generator seed (reproducible).
    pub seed: u64,
    relations: BTreeMap<RelId, Relation>,
}

impl Database {
    /// One relation by id.
    pub fn rel(&self, id: RelId) -> &Relation {
        &self.relations[&id]
    }

    /// All relations in [`RelId`] order (base-image serialization).
    pub fn relations(&self) -> impl Iterator<Item = &Relation> {
        self.relations.values()
    }

    /// Rebuild a database from persisted relations (base-image recovery,
    /// [`crate::storage`]): the inverse of walking [`Database::relations`]
    /// through [`Relation::columns`].
    pub fn from_parts(sf: f64, seed: u64, relations: Vec<Relation>) -> Database {
        Database {
            sf,
            seed,
            relations: relations.into_iter().map(|r| (r.id, r)).collect(),
        }
    }

    /// Mutable access to one relation (the baseline DML mirror path,
    /// [`crate::exec::baseline::apply_dml`]).
    pub fn rel_mut(&mut self, id: RelId) -> &mut Relation {
        self.relations.get_mut(&id).expect("relation exists")
    }

    /// Generate all relations at scale factor `sf` (sim scale; the report
    /// scale stays SF=1000 in the timing model).
    pub fn generate(sf: f64, seed: u64) -> Database {
        let root = Rng::new(seed);
        let mut relations = BTreeMap::new();

        let n_part = RelId::Part.records_at_sf(sf) as usize;
        let n_supp = RelId::Supplier.records_at_sf(sf) as usize;
        let n_ps = RelId::Partsupp.records_at_sf(sf) as usize;
        let n_cust = RelId::Customer.records_at_sf(sf) as usize;
        let n_ord = RelId::Orders.records_at_sf(sf) as usize;

        relations.insert(RelId::Part, gen_part(&mut root.stream(1), n_part));
        relations.insert(RelId::Supplier, gen_supplier(&mut root.stream(2), n_supp));
        relations.insert(
            RelId::Partsupp,
            gen_partsupp(&mut root.stream(3), n_ps, n_part, n_supp),
        );
        relations.insert(RelId::Customer, gen_customer(&mut root.stream(4), n_cust));
        let (orders, lineitem) =
            gen_orders_lineitem(&mut root.stream(5), n_ord, n_cust, n_part, n_supp);
        relations.insert(RelId::Orders, orders);
        relations.insert(RelId::Lineitem, lineitem);
        relations.insert(RelId::Nation, gen_nation());
        relations.insert(RelId::Region, gen_region());

        Database {
            sf,
            seed,
            relations,
        }
    }
}

/// Intern a parsed column name to the schema's `&'static str` (base-image
/// recovery). PIM relations resolve through [`schema::attr`]; the non-PIM
/// dimension tables (NATION/REGION) carry only the join keys dbgen emits.
pub fn intern_column(id: RelId, name: &str) -> Option<&'static str> {
    if let Some(a) = schema::attr(id, name) {
        return Some(a.name);
    }
    const NON_PIM: &[&str] = &["n_nationkey", "n_regionkey", "r_regionkey"];
    NON_PIM.iter().find(|&&n| n == name).copied()
}

/// Spec §4.2.3: p_retailprice from the part key alone (no lookup needed
/// when deriving l_extendedprice), in cents.
pub fn retail_price_cents(partkey: u64) -> u64 {
    90_000 + ((partkey / 10) % 20_001) + 100 * (partkey % 1_000)
}

fn gen_part(rng: &mut Rng, n: usize) -> Relation {
    let mut r = Relation::new(RelId::Part, n);
    let mut partkey = Vec::with_capacity(n);
    let mut mfgr = Vec::with_capacity(n);
    let mut brand = Vec::with_capacity(n);
    let mut ptype = Vec::with_capacity(n);
    let mut size = Vec::with_capacity(n);
    let mut container = Vec::with_capacity(n);
    let mut price = Vec::with_capacity(n);
    for i in 0..n as u64 {
        let pk = i + 1;
        partkey.push(pk);
        let m = rng.range_u64(0, 4);
        mfgr.push(m);
        // brand is within the manufacturer family (spec: Brand#MN, M=mfgr)
        brand.push(m * 5 + rng.range_u64(0, 4));
        ptype.push(rng.range_u64(0, 149));
        size.push(rng.range_u64(1, 50));
        container.push(rng.range_u64(0, 39));
        price.push(retail_price_cents(pk));
    }
    r.push("p_partkey", partkey);
    r.push("p_mfgr", mfgr);
    r.push("p_brand", brand);
    r.push("p_type", ptype);
    r.push("p_size", size);
    r.push("p_container", container);
    r.push("p_retailprice", price);
    r
}

fn gen_supplier(rng: &mut Rng, n: usize) -> Relation {
    let mut r = Relation::new(RelId::Supplier, n);
    let mut suppkey = Vec::with_capacity(n);
    let mut nation = Vec::with_capacity(n);
    let mut phone = Vec::with_capacity(n);
    let mut phone_rest = Vec::with_capacity(n);
    let mut acctbal = Vec::with_capacity(n);
    for i in 0..n as u64 {
        suppkey.push(i + 1);
        let nk = rng.range_u64(0, 24);
        nation.push(nk);
        phone.push(nk + 10);
        phone_rest.push(rng.range_u64(0, 9_999_999_999)); // 10 local digits
        // spec: [-999.99, 9999.99] -> offset by +1000.00
        acctbal.push((rng.range_i64(-99_999, 999_999) + 100_000) as u64);
    }
    r.push("s_suppkey", suppkey);
    r.push("s_nationkey", nation);
    r.push("s_phone_cc", phone);
    r.push("s_phone_rest", phone_rest);
    r.push("s_acctbal", acctbal);
    r
}

fn gen_partsupp(rng: &mut Rng, n: usize, n_part: usize, n_supp: usize) -> Relation {
    let mut r = Relation::new(RelId::Partsupp, n);
    let mut partkey = Vec::with_capacity(n);
    let mut suppkey = Vec::with_capacity(n);
    let mut availqty = Vec::with_capacity(n);
    let mut cost = Vec::with_capacity(n);
    for i in 0..n as u64 {
        // 4 suppliers per part, spread over the supplier space (spec §4.2.3)
        let pk = i / 4 % n_part.max(1) as u64 + 1;
        let sk = (pk + (i % 4) * ((n_supp as u64 / 4).max(1) + 1)) % n_supp.max(1) as u64 + 1;
        partkey.push(pk);
        suppkey.push(sk);
        availqty.push(rng.range_u64(1, 9_999));
        cost.push(rng.range_u64(100, 100_000));
    }
    r.push("ps_partkey", partkey);
    r.push("ps_suppkey", suppkey);
    r.push("ps_availqty", availqty);
    r.push("ps_supplycost", cost);
    r
}

fn gen_customer(rng: &mut Rng, n: usize) -> Relation {
    let mut r = Relation::new(RelId::Customer, n);
    let mut custkey = Vec::with_capacity(n);
    let mut nation = Vec::with_capacity(n);
    let mut phone = Vec::with_capacity(n);
    let mut phone_rest = Vec::with_capacity(n);
    let mut acctbal = Vec::with_capacity(n);
    let mut segment = Vec::with_capacity(n);
    for i in 0..n as u64 {
        custkey.push(i + 1);
        let nk = rng.range_u64(0, 24);
        nation.push(nk);
        phone.push(nk + 10);
        phone_rest.push(rng.range_u64(0, 9_999_999_999)); // 10 local digits
        acctbal.push((rng.range_i64(-99_999, 999_999) + 100_000) as u64);
        segment.push(rng.range_u64(0, 4));
    }
    r.push("c_custkey", custkey);
    r.push("c_nationkey", nation);
    r.push("c_phone_cc", phone);
    r.push("c_phone_rest", phone_rest);
    r.push("c_acctbal", acctbal);
    r.push("c_mktsegment", segment);
    r
}

fn gen_orders_lineitem(
    rng: &mut Rng,
    n_orders: usize,
    n_cust: usize,
    n_part: usize,
    n_supp: usize,
) -> (Relation, Relation) {
    let cutoff = schema::date(1995, 6, 17); // spec CURRENTDATE
    let max_od = schema::max_orderdate();

    let mut o_orderkey = Vec::with_capacity(n_orders);
    let mut o_custkey = Vec::with_capacity(n_orders);
    let mut o_status = Vec::with_capacity(n_orders);
    let mut o_totalprice = Vec::with_capacity(n_orders);
    let mut o_orderdate = Vec::with_capacity(n_orders);
    let mut o_priority = Vec::with_capacity(n_orders);
    let mut o_shippriority = Vec::with_capacity(n_orders);

    let mut l: BTreeMap<&'static str, Vec<u64>> = BTreeMap::new();
    let cols = [
        "l_orderkey",
        "l_partkey",
        "l_suppkey",
        "l_linenumber",
        "l_quantity",
        "l_extendedprice",
        "l_discount",
        "l_tax",
        "l_returnflag",
        "l_linestatus",
        "l_shipdate",
        "l_commitdate",
        "l_receiptdate",
        "l_shipinstruct",
        "l_shipmode",
    ];
    for c in cols {
        l.insert(c, Vec::with_capacity(n_orders * 4));
    }

    for i in 0..n_orders as u64 {
        let orderkey = i * 4 + 1; // sparse keys as in the spec
        let orderdate = rng.range_u64(0, max_od);
        o_orderkey.push(orderkey);
        o_custkey.push(rng.range_u64(1, n_cust.max(1) as u64));
        o_orderdate.push(orderdate);
        o_priority.push(rng.range_u64(0, 4));
        o_shippriority.push(0);

        let lines = rng.range_u64(1, 7) as usize;
        let mut total = 0u64;
        let mut all_f = true;
        let mut all_o = true;
        for ln in 0..lines {
            let partkey = rng.range_u64(1, n_part.max(1) as u64);
            let quantity = rng.range_u64(1, 50);
            let eprice = quantity * retail_price_cents(partkey) / 100;
            let shipdate = orderdate + rng.range_u64(1, 121);
            let commitdate = orderdate + rng.range_u64(30, 90);
            let receiptdate = shipdate + rng.range_u64(1, 30);
            let returnflag = if receiptdate <= cutoff {
                rng.range_u64(0, 1) // R or A
            } else {
                2 // N
            };
            let linestatus = if shipdate > cutoff { 0 } else { 1 }; // O / F
            all_f &= linestatus == 1;
            all_o &= linestatus == 0;
            total += eprice;

            l.get_mut("l_orderkey").unwrap().push(orderkey);
            l.get_mut("l_partkey").unwrap().push(partkey);
            l.get_mut("l_suppkey")
                .unwrap()
                .push(rng.range_u64(1, n_supp.max(1) as u64));
            l.get_mut("l_linenumber").unwrap().push(ln as u64 + 1);
            l.get_mut("l_quantity").unwrap().push(quantity);
            l.get_mut("l_extendedprice").unwrap().push(eprice);
            l.get_mut("l_discount").unwrap().push(rng.range_u64(0, 10));
            l.get_mut("l_tax").unwrap().push(rng.range_u64(0, 8));
            l.get_mut("l_returnflag").unwrap().push(returnflag);
            l.get_mut("l_linestatus").unwrap().push(linestatus);
            l.get_mut("l_shipdate").unwrap().push(shipdate);
            l.get_mut("l_commitdate").unwrap().push(commitdate);
            l.get_mut("l_receiptdate").unwrap().push(receiptdate);
            l.get_mut("l_shipinstruct").unwrap().push(rng.range_u64(0, 3));
            l.get_mut("l_shipmode").unwrap().push(rng.range_u64(0, 6));
        }
        // spec: F if all lines F, O if all lines O, else P
        o_status.push(if all_f {
            0
        } else if all_o {
            1
        } else {
            2
        });
        o_totalprice.push(total);
    }

    let mut orders = Relation::new(RelId::Orders, n_orders);
    orders.push("o_orderkey", o_orderkey);
    orders.push("o_custkey", o_custkey);
    orders.push("o_orderstatus", o_status);
    orders.push("o_totalprice", o_totalprice);
    orders.push("o_orderdate", o_orderdate);
    orders.push("o_orderpriority", o_priority);
    orders.push("o_shippriority", o_shippriority);

    let n_li = l["l_orderkey"].len();
    let mut lineitem = Relation::new(RelId::Lineitem, n_li);
    for c in cols {
        lineitem.push(c, l.remove(c).unwrap());
    }
    (orders, lineitem)
}

fn gen_nation() -> Relation {
    let mut r = Relation::new(RelId::Nation, 25);
    r.push("n_nationkey", (0..25).collect());
    r.push(
        "n_regionkey",
        schema::NATIONS.iter().map(|&(_, reg)| reg as u64).collect(),
    );
    r
}

fn gen_region() -> Relation {
    let mut r = Relation::new(RelId::Region, 5);
    r.push("r_regionkey", (0..5).collect());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Database {
        Database::generate(0.001, 7)
    }

    #[test]
    fn from_parts_round_trips_a_mutated_image() {
        let mut db = tiny();
        db.rel_mut(RelId::Part).set_valid(2, false);
        db.rel_mut(RelId::Part).zero_row(2);
        let rebuilt = Database::from_parts(
            db.sf,
            db.seed,
            db.relations()
                .map(|r| {
                    Relation::from_parts(
                        r.id,
                        r.columns()
                            .map(|(n, c)| {
                                (intern_column(r.id, n).expect("interns"), c.to_vec())
                            })
                            .collect(),
                        (0..r.records).map(|i| r.live(i)).collect(),
                    )
                })
                .collect(),
        );
        for r in db.relations() {
            let b = rebuilt.rel(r.id);
            assert_eq!(b.records, r.records);
            assert_eq!(b.live_count(), r.live_count());
            for (n, c) in r.columns() {
                assert_eq!(b.col(n), c);
            }
        }
        assert!(!rebuilt.rel(RelId::Part).live(2));
    }

    #[test]
    fn deterministic() {
        let a = Database::generate(0.001, 7);
        let b = Database::generate(0.001, 7);
        assert_eq!(
            a.rel(RelId::Lineitem).col("l_shipdate"),
            b.rel(RelId::Lineitem).col("l_shipdate")
        );
        let c = Database::generate(0.001, 8);
        assert_ne!(
            a.rel(RelId::Lineitem).col("l_shipdate"),
            c.rel(RelId::Lineitem).col("l_shipdate")
        );
    }

    #[test]
    fn record_counts_scale() {
        let db = tiny();
        assert_eq!(db.rel(RelId::Part).records, 200);
        assert_eq!(db.rel(RelId::Orders).records, 1500);
        let li = db.rel(RelId::Lineitem).records;
        assert!((3000..=10_500).contains(&li), "lineitem {li}");
    }

    #[test]
    fn values_fit_declared_widths() {
        let db = tiny();
        for rel in schema::PIM_RELATIONS {
            let r = db.rel(rel);
            for a in schema::attrs(rel) {
                let max = r.col(a.name).iter().copied().max().unwrap_or(0);
                assert!(
                    max < (1u64 << a.bits),
                    "{:?}.{} max {max} exceeds {} bits",
                    rel,
                    a.name,
                    a.bits
                );
            }
        }
    }

    #[test]
    fn date_relationships_hold() {
        let db = tiny();
        let li = db.rel(RelId::Lineitem);
        let ship = li.col("l_shipdate");
        let commit = li.col("l_commitdate");
        let receipt = li.col("l_receiptdate");
        for i in 0..li.records {
            assert!(receipt[i] > ship[i]);
            assert!(commit[i] >= ship[i].saturating_sub(121) ); // same order window
        }
        // both orderings of commit vs receipt occur (Q4/Q12/Q21 predicates)
        let lt = (0..li.records).filter(|&i| commit[i] < receipt[i]).count();
        assert!(lt > 0 && lt < li.records);
    }

    #[test]
    fn q6_style_selectivity_reasonable() {
        // Q6 selects shipdate in 1994, discount in [5,7], qty < 24:
        // spec selectivity ~ (1/7) * (3/11) * (23/50) ≈ 1.8%
        let db = Database::generate(0.01, 3);
        let li = db.rel(RelId::Lineitem);
        let (d0, d1) = (schema::date(1994, 1, 1), schema::date(1995, 1, 1));
        let n = li.records;
        let sel = (0..n)
            .filter(|&i| {
                let sd = li.col("l_shipdate")[i];
                let disc = li.col("l_discount")[i];
                let q = li.col("l_quantity")[i];
                sd >= d0 && sd < d1 && (5..=7).contains(&disc) && q < 24
            })
            .count() as f64
            / n as f64;
        assert!((0.005..0.04).contains(&sel), "selectivity {sel}");
    }

    #[test]
    fn returnflag_linestatus_follow_cutoff() {
        let db = tiny();
        let li = db.rel(RelId::Lineitem);
        let cutoff = schema::date(1995, 6, 17);
        for i in 0..li.records {
            let rf = li.col("l_returnflag")[i];
            let rd = li.col("l_receiptdate")[i];
            if rd > cutoff {
                assert_eq!(rf, 2); // N
            } else {
                assert!(rf < 2); // R or A
            }
            let ls = li.col("l_linestatus")[i];
            assert_eq!(ls == 0, li.col("l_shipdate")[i] > cutoff);
        }
    }

    #[test]
    fn orderstatus_consistent_with_lines() {
        let db = tiny();
        let ord = db.rel(RelId::Orders);
        // all three statuses appear
        let mut seen = [false; 3];
        for &s in ord.col("o_orderstatus") {
            seen[s as usize] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    #[should_panic(expected = "no column")]
    fn missing_column_panics() {
        tiny().rel(RelId::Part).col("bogus");
    }

    #[test]
    fn mutators_track_liveness_and_values() {
        let mut db = tiny();
        let part = db.rel_mut(RelId::Part);
        let n = part.records;
        assert_eq!(part.live_count(), n);
        assert!(part.live(0));

        part.set_valid(0, false);
        part.zero_row(0);
        assert!(!part.live(0));
        assert_eq!(part.live_count(), n - 1);
        assert_eq!(part.col("p_partkey")[0], 0);

        part.write("p_size", 1, 33);
        assert_eq!(part.col("p_size")[1], 33);

        let row = part.append_row(&[("p_partkey", 999_999), ("p_size", 7)]);
        assert_eq!(row, n);
        assert_eq!(part.records, n + 1);
        assert!(part.live(row));
        assert_eq!(part.col("p_partkey")[row], 999_999);
        assert_eq!(part.col("p_brand")[row], 0); // unlisted columns zero
        assert_eq!(part.live_count(), n);
    }
}
