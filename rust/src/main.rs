//! PIMDB command-line entrypoint (Layer-3 leader).
//!
//! `pimdb run --query Q6` executes one TPC-H query on the PIMDB engine
//! (native or PJRT functional backend) and prints the result plus the full
//! metric set; `pimdb run --sql "from lineitem | ..."` does the same for
//! an ad-hoc PQL text query (`--sql-file` reads the text from disk);
//! `pimdb report --exp figN/tableN` regenerates the paper's evaluation
//! artifacts. See `pimdb help`.

use pimdb::api::Pimdb;
use pimdb::cli::{Args, USAGE};
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::db::schema::PIM_RELATIONS;
use pimdb::error::PimdbError;
use pimdb::exec::metrics::RunReport;
use pimdb::exec::plan::resolve_parallelism;
use pimdb::exec::{baseline, pimdb as engine};
use pimdb::mem::addr::AddressMap;
use pimdb::pim::controller::cost;
use pimdb::pim::isa::{ColRange, Opcode, PimInstruction};
use pimdb::query::ast::{Query, Statement};
use pimdb::report;
use pimdb::util::stats::eng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<(), String> {
    match args.command.as_str() {
        "run" => cmd_run(args),
        "report" => cmd_report(args),
        "gen-data" => cmd_gen_data(args),
        "addrmap" => cmd_addrmap(),
        "inspect" => cmd_inspect(args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n\n{USAGE}")),
    }
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let cfg = args.build_config()?;
    // --query TPC-H names, or ad-hoc PQL text (queries and/or DML
    // statements) via --sql / --sql-file
    let statements: Vec<Statement> = args.statements()?;
    let seed = args.parse_u64("seed")?.unwrap_or(42);
    let engine_kind = args.engine()?;

    let t0 = std::time::Instant::now();
    // --data-dir opens a durable handle: first use initializes the
    // directory, later runs recover (checkpoint load + WAL replay) so
    // DML from earlier invocations is still visible
    let db = match args.durability()? {
        Some(dcfg) => {
            let db = Pimdb::open_durable(cfg.clone(), dcfg)?;
            if let Some(s) = db.durability_stats() {
                if s.wal_records_replayed > 0 || s.torn_tails_truncated > 0 {
                    println!(
                        "-- recovered: {} wal record{} replayed, {} torn tail{} truncated --",
                        s.wal_records_replayed,
                        if s.wal_records_replayed == 1 { "" } else { "s" },
                        s.torn_tails_truncated,
                        if s.torn_tails_truncated == 1 { "" } else { "s" },
                    );
                }
            }
            db
        }
        None => Pimdb::open(cfg.clone(), Database::generate(cfg.sim_sf, seed))?,
    };
    if args.has("explain") {
        for s in &statements {
            match s {
                Statement::Query(q) => {
                    let text = pimdb::query::opt::explain_query(
                        q,
                        db.layout(),
                        cfg.xbar_cols,
                        cfg.xbar_rows,
                        cfg.opt_level,
                    )
                    .map_err(PimdbError::from)?;
                    print!("{text}");
                    // zone-map pruning decisions next to the disassembly:
                    // per-shard skip bitmap, zone ranges consulted, and
                    // the cost-ordered predicate sequence
                    print!("{}", db.explain_pruning(q)?);
                }
                Statement::Dml(d) => {
                    let text = pimdb::query::opt::explain_dml(
                        d,
                        db.layout(),
                        cfg.xbar_cols,
                        cfg.xbar_rows,
                    )
                    .map_err(PimdbError::from)?;
                    print!("{text}");
                }
            }
        }
    }

    let has_dml = statements
        .iter()
        .any(|s| matches!(s, Statement::Dml(_)));
    let n_stmts = statements.len();
    if has_dml {
        // mixed ingest+analytics program: statements execute strictly in
        // source order (a DML statement changes what later queries see).
        // With --baseline a host column-store mirror receives the
        // identical mutations, so the comparison tracks the mutated data.
        let mut mirror = args.has("baseline").then(|| db.database().clone());
        for s in &statements {
            match s {
                Statement::Query(q) => {
                    let r = db.prepare(q)?.execute_on(engine_kind)?;
                    print_report(&cfg, engine_kind, r.raw_report());
                    if let Some(m) = &mirror {
                        print_baseline(&cfg, m, q, r.raw_report());
                    }
                }
                Statement::Dml(d) => {
                    let r = db.prepare_dml(d)?.execute_on(engine_kind)?;
                    print_dml_report(&db, d, &r);
                    if let Some(m) = &mut mirror {
                        let b = baseline::apply_dml(&cfg, m, d);
                        println!(
                            "-- baseline mirror: {} rows affected ({}) --",
                            b.rows_affected,
                            if b.rows_affected == r.rows_affected {
                                "matches PIM"
                            } else {
                                "MISMATCH vs PIM!"
                            }
                        );
                    }
                }
            }
        }
    } else {
        // query-only program: prepare everything up front (errors before
        // any execution), then execute all statements concurrently from
        // &db: queries on disjoint relations overlap (the wave-scheduler
        // rule, enforced by the per-relation locks), each fanning out
        // over the shard pool. Results come back in input order,
        // bit-identical to a serial loop.
        let queries: Vec<&Query> = statements
            .iter()
            .map(|s| match s {
                Statement::Query(q) => q,
                Statement::Dml(_) => unreachable!("checked above"),
            })
            .collect();
        let prepared = queries
            .iter()
            .map(|q| db.prepare(*q))
            .collect::<Result<Vec<_>, _>>()?;
        let results = std::thread::scope(|s| {
            let workers: Vec<_> = prepared
                .iter()
                .map(|p| s.spawn(move || p.execute_on(engine_kind)))
                .collect();
            workers
                .into_iter()
                .map(|w| w.join().expect("query worker panicked"))
                .collect::<Result<Vec<_>, _>>()
        })?;
        for (q, r) in queries.iter().zip(&results) {
            print_report(&cfg, engine_kind, r.raw_report());
            if args.has("baseline") {
                print_baseline(&cfg, db.database(), q, r.raw_report());
            }
        }
    }
    if args.has("checkpoint") {
        if args.durability()?.is_none() {
            return Err("--checkpoint needs --data-dir".into());
        }
        let bytes = db.checkpoint()?;
        println!("-- checkpoint written ({bytes} bytes) --");
    }
    let wall = t0.elapsed();
    println!(
        "(host wall-clock for {} simulated statement{}: {:.2?} at parallelism {})",
        n_stmts,
        if n_stmts == 1 { "" } else { "s" },
        wall,
        resolve_parallelism(cfg.parallelism)
    );
    Ok(())
}

fn print_dml_report(db: &Pimdb, d: &pimdb::query::ast::Dml, r: &pimdb::api::DmlResult) {
    println!(
        "dml {} on {}: {} row{} affected",
        d.kind_name(),
        d.rel().name(),
        r.rows_affected,
        if r.rows_affected == 1 { "" } else { "s" }
    );
    let m = &r.metrics;
    println!(
        "  live records   {} (sim scale)",
        db.live_records(d.rel())
    );
    println!(
        "  exec time      {}s, llc misses {}, energy {}J",
        eng(m.exec_time_s),
        m.llc_misses,
        eng(m.total_energy_pj() * 1e-12)
    );
    println!(
        "  wear delta     {:.6} ops/cell on the hottest row (10yr {})",
        r.wear_delta,
        eng(m.required_endurance_10yr)
    );
}

fn print_report(cfg: &SystemConfig, engine_kind: engine::EngineKind, r: &RunReport) {
    println!("query {} [{:?} engine], sim SF={}, report SF={}", r.query, engine_kind, cfg.sim_sf, cfg.report_sf);
    for (rel, n) in &r.output.selected {
        println!("  {rel}: {n} records pass the filter (sim scale)");
    }
    for g in &r.output.groups {
        let key: Vec<String> = g.key.iter().map(|(k, v)| format!("{k}={v}")).collect();
        println!("  group [{}] count={}", key.join(","), g.count);
        for (label, v) in &g.values {
            println!("    {label} = {v}");
        }
    }
    let m = &r.metrics;
    println!("-- modelled at SF={} --", cfg.report_sf);
    println!("  exec time      {}s (pim {}s, read {}s, other {}s)",
        eng(m.exec_time_s), eng(m.pim_time_s), eng(m.read_time_s), eng(m.other_time_s));
    println!("  llc misses     {}", m.llc_misses);
    println!("  energy         {}J (host {}J, dram {}J, pim {}J)",
        eng(m.total_energy_pj() * 1e-12),
        eng(m.host_energy_pj * 1e-12),
        eng(m.dram_energy_pj * 1e-12),
        eng(m.pim_energy.total_pj() * 1e-12));
    println!("  cycles/xbar    filter {} arith {} coltrans {} agg {}/{}",
        m.cycles.filter, m.cycles.arith, m.cycles.col_transform,
        m.cycles.agg_col, m.cycles.agg_row);
    println!("  optimizer      -{}: {} -> {} steps, {} -> {} cycles, {} -> {} inter cells",
        cfg.opt_level,
        m.opt.steps_before, m.opt.steps_after,
        m.opt.cycles_before, m.opt.cycles_after,
        m.opt.inter_before, m.opt.inter_after);
    println!("  pruning        {} shards skipped, {} steps short-circuited",
        m.shards_skipped, m.steps_short_circuited);
    println!("  chip power     peak {:.2} W, avg {:.3} W, theoretical {:.0} W",
        m.peak_chip_w, m.avg_chip_w, m.theoretical_chip_w);
    println!("  endurance      {:.4} ops/cell/exec, 10yr {}",
        m.ops_per_cell, eng(m.required_endurance_10yr));
}

fn print_baseline(cfg: &SystemConfig, db: &Database, q: &Query, r: &RunReport) {
    let m = &r.metrics;
    let b = baseline::run_query(cfg, db, q);
    println!("-- baseline (in-memory column store) --");
    println!("  exec time      {}s", eng(b.metrics.exec_time_s));
    println!("  llc misses     {}", b.metrics.llc_misses);
    println!("  energy         {}J", eng(b.metrics.total_energy_pj() * 1e-12));
    println!("  speedup        {:.2}x", b.metrics.exec_time_s / m.exec_time_s);
    println!("  llc reduction  {:.2}x", b.metrics.llc_misses as f64 / m.llc_misses.max(1) as f64);
    println!("  energy saving  {:.2}x", b.metrics.total_energy_pj() / m.total_energy_pj());
    if b.output != r.output {
        println!("  WARNING: functional outputs differ between engines!");
    } else {
        println!("  functional outputs match the baseline");
    }
}

fn cmd_report(args: &Args) -> Result<(), String> {
    let cfg = args.build_config()?;
    let exp = args.get_or("exp", "all").to_string();
    let engine_kind = args.engine()?;
    let ids: Vec<&str> = if exp == "all" {
        report::EXPERIMENTS.to_vec()
    } else {
        vec![exp.as_str()]
    };
    let needs_runs = ids.iter().any(|e| report::needs_runs(e));
    let exps = if needs_runs {
        eprintln!(
            "running all 19 queries on PIMDB + baseline (sim SF={}) ...",
            cfg.sim_sf
        );
        Some(report::Experiments::run(&cfg, engine_kind)?)
    } else {
        None
    };
    for id in ids {
        report::print_experiment(id, &cfg, exps.as_ref())?;
        println!();
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<(), String> {
    let cfg = args.build_config()?;
    let seed = args.parse_u64("seed")?.unwrap_or(42);
    let t0 = std::time::Instant::now();
    let db = Database::generate(cfg.sim_sf, seed);
    println!("TPC-H data at SF={} (seed {seed}), generated in {:.2?}:", cfg.sim_sf, t0.elapsed());
    for rel in PIM_RELATIONS {
        let r = db.rel(rel);
        println!(
            "  {:<10} {:>10} records, {:>2} columns",
            rel.name(),
            r.records,
            r.column_names().len()
        );
    }
    Ok(())
}

fn cmd_addrmap() -> Result<(), String> {
    let m = AddressMap::paper_default();
    println!("Fig. 3 physical-address/cell mapping (1 GB pages, 1024x512 crossbars):");
    for (name, shift, width) in m.fields() {
        println!("  bits [{:>2}..{:>2}) {name}", shift, shift + width);
    }
    println!(
        "{} crossbars/page, {} rows, {} crossbars per 64 B line access",
        m.xbars_per_page(),
        m.rows(),
        m.xbars_per_line()
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<(), String> {
    let n = args.parse_u64("n")?.unwrap_or(32) as usize;
    let imm = args.parse_u64("imm")?.unwrap_or(0xF0F0_F0F0);
    let op_name = args.get_or("op", "all");
    let a = ColRange::new(0, n);
    let b = ColRange::new(64, n);
    let d = ColRange::new(128, 1);
    let all: Vec<(&str, PimInstruction)> = vec![
        ("eq_imm", PimInstruction::with_imm(Opcode::EqImm, a, d, imm)),
        ("ne_imm", PimInstruction::with_imm(Opcode::NeImm, a, d, imm)),
        ("lt_imm", PimInstruction::with_imm(Opcode::LtImm, a, d, imm)),
        ("gt_imm", PimInstruction::with_imm(Opcode::GtImm, a, d, imm)),
        ("add_imm", PimInstruction::with_imm(Opcode::AddImm, a, a, imm)),
        ("eq", PimInstruction::binary(Opcode::Eq, a, b, d)),
        ("lt", PimInstruction::binary(Opcode::Lt, a, b, d)),
        ("set", PimInstruction::unary(Opcode::Set, a, a)),
        ("not", PimInstruction::unary(Opcode::Not, a, a)),
        ("and", PimInstruction::binary(Opcode::And, a, b, a)),
        ("or", PimInstruction::binary(Opcode::Or, a, b, a)),
        ("add", PimInstruction::binary(Opcode::Add, a, b, a)),
        ("mul", PimInstruction::binary(Opcode::Mul, a, b, a)),
        ("reduce_sum", PimInstruction::unary(Opcode::ReduceSum, a, a)),
        ("reduce_min", PimInstruction::unary(Opcode::ReduceMin, a, a)),
        ("column_transform", PimInstruction::unary(Opcode::ColumnTransform, d, d)),
    ];
    println!("instruction costs (n={n}, imm={imm:#x}, 1024-row crossbar):");
    for (name, i) in all {
        if op_name != "all" && op_name != name {
            continue;
        }
        let c = cost(&i, 1024);
        println!(
            "  {:<18} {:>8} cycles ({} col + {} row), {} intermediate cells, {} us at 30ns",
            name,
            c.total_cycles(),
            c.col_cycles,
            c.row_cycles,
            c.intermediate_cells,
            c.total_cycles() as f64 * 0.03
        );
    }
    Ok(())
}
