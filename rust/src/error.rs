//! Crate-wide error type for the PIMDB service API.
//!
//! Every fallible path of the embedding API ([`crate::api`]) and the
//! engine underneath it returns [`PimdbError`]: a typed union of the
//! layer-specific errors (PQL diagnostics, compile errors, layout errors,
//! execution errors) instead of pre-rendered strings. Callers can match
//! on the variant programmatically; the CLI renders it exactly once at
//! the process boundary via the `impl From<PimdbError> for String`.

use crate::db::layout::LayoutError;
use crate::exec::ExecError;
use crate::query::compiler::CompileError;
use crate::query::lang::Diag;

/// Any error the PIMDB service API can return.
#[derive(Clone, Debug)]
pub enum PimdbError {
    /// PQL text failed to parse or lower. Carries the diagnostic *and*
    /// the source text so [`std::fmt::Display`] can render the caret
    /// listing without the caller re-supplying the source.
    Parse {
        /// The parser/lowering diagnostic (message + source span).
        diag: Diag,
        /// The PQL source text the diagnostic refers to.
        src: String,
    },
    /// The query compiler rejected a relation program.
    Compile(CompileError),
    /// The database copy does not fit the configured PIM geometry.
    Layout(LayoutError),
    /// A functional execution backend failed at runtime.
    Exec(ExecError),
    /// `prepare` was given a TPC-H query name outside the evaluated set.
    UnknownQuery(String),
    /// `prepare` was given a PQL program with several query blocks
    /// (use [`crate::api::Pimdb::prepare_all`] for programs).
    ExpectedSingleQuery {
        /// Query blocks the program actually contained.
        found: usize,
    },
    /// [`crate::api::Pimdb::open`] rejected an inconsistent
    /// [`crate::config::SystemConfig`] (e.g. an explicit admission cap
    /// below the shard-worker count, which would leave workers
    /// permanently idle behind the admission gate).
    Config(String),
    /// Durable state on disk failed validation: a checksum mismatch in a
    /// complete WAL record, a checkpoint whose digest does not cover its
    /// bytes, an epoch gap in the replay sequence, or a record that does
    /// not decode back to a canonical DML statement. Recovery refuses the
    /// data rather than guessing ([`crate::api::Pimdb::open_durable`]).
    Corrupt(String),
    /// An operating-system I/O failure while reading or writing the data
    /// directory (WAL append, checkpoint write, recovery scan). Carries
    /// the rendered `std::io::Error` text; the error type stays `Clone`.
    Io(String),
}

impl std::fmt::Display for PimdbError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PimdbError::Parse { diag, src } => write!(f, "{}", diag.render(src)),
            PimdbError::Compile(e) => write!(f, "{e}"),
            PimdbError::Layout(e) => write!(f, "{e}"),
            PimdbError::Exec(e) => write!(f, "{e}"),
            PimdbError::UnknownQuery(name) => {
                write!(f, "unknown query '{name}' (not in the evaluated TPC-H set)")
            }
            PimdbError::ExpectedSingleQuery { found } => write!(
                f,
                "expected a single query block, got {found} (use prepare_all)"
            ),
            PimdbError::Config(msg) => write!(f, "invalid configuration: {msg}"),
            PimdbError::Corrupt(msg) => write!(f, "corrupt durable state: {msg}"),
            PimdbError::Io(msg) => write!(f, "i/o error: {msg}"),
        }
    }
}

impl std::error::Error for PimdbError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PimdbError::Compile(e) => Some(e),
            PimdbError::Layout(e) => Some(e),
            PimdbError::Exec(e) => Some(e),
            _ => None,
        }
    }
}

impl From<CompileError> for PimdbError {
    fn from(e: CompileError) -> PimdbError {
        PimdbError::Compile(e)
    }
}

impl From<LayoutError> for PimdbError {
    fn from(e: LayoutError) -> PimdbError {
        PimdbError::Layout(e)
    }
}

impl From<ExecError> for PimdbError {
    fn from(e: ExecError) -> PimdbError {
        PimdbError::Exec(e)
    }
}

/// Render at the process boundary (the CLI's `Result<(), String>` paths).
impl From<PimdbError> for String {
    fn from(e: PimdbError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::lang::Span;

    #[test]
    fn display_renders_each_variant() {
        let parse = PimdbError::Parse {
            diag: Diag::new("unknown column 'nope'", Span::new(5, 9)),
            src: "from nope | filter true".into(),
        };
        let text = parse.to_string();
        assert!(text.contains("unknown column"), "{text}");
        assert!(text.contains('^'), "{text}");

        let unk = PimdbError::UnknownQuery("Q99".into());
        assert!(unk.to_string().contains("Q99"));

        let multi = PimdbError::ExpectedSingleQuery { found: 3 };
        assert!(multi.to_string().contains('3'));

        let config = PimdbError::Config("admission cap 2 is below parallelism 4".into());
        let text = config.to_string();
        assert!(text.contains("invalid configuration"), "{text}");
        assert!(text.contains("admission cap 2"), "{text}");

        let corrupt = PimdbError::Corrupt("wal record 3 checksum mismatch".into());
        let text = corrupt.to_string();
        assert!(text.contains("corrupt durable state"), "{text}");
        assert!(text.contains("record 3"), "{text}");

        let io = PimdbError::Io("permission denied (os error 13)".into());
        let text = io.to_string();
        assert!(text.contains("i/o error"), "{text}");
        assert!(text.contains("denied"), "{text}");
    }

    #[test]
    fn error_sources_chain() {
        use std::error::Error;
        let e = PimdbError::Layout(LayoutError::RowTooWide {
            rel: crate::db::schema::RelId::Part,
            row_bits: 600,
            xbar_cols: 512,
        });
        assert!(e.source().is_some());
        assert!(e.to_string().contains("exceeds crossbar"));
        let s: String = e.into();
        assert!(s.contains("600"));
    }
}
