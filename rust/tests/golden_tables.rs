//! Golden snapshots of the Table 5/6-style report output for a fixed
//! seed/scale, so report regressions are caught by `cargo test`.
//!
//! Two snapshots exist since the optimizer landed:
//!
//! * `tests/golden/tables_sf0.002_seed42.txt` — pinned at **-O0**: the
//!   compiler's naive instruction streams. This is the original pre-
//!   optimizer reference and must never move unless the compiler itself
//!   changes.
//! * `tests/golden/tables_sf0.002_seed42_O2.txt` — the **-O2** default
//!   the engine actually runs: fewer cycles, never more intermediate
//!   cells.
//!
//! Semantics (PR 2 removed the *silent* self-bless from PR 1):
//!
//! * snapshot present — rendered tables must match it byte-for-byte;
//! * snapshot missing, local run — the test blesses the file with a loud
//!   warning so the contributor commits it;
//! * snapshot missing in GitHub CI (`GITHUB_ACTIONS` set) — the test
//!   FAILS: CI may never bless its own reference. The workflow
//!   additionally refuses untracked files under `tests/golden/`, so a
//!   blessing run can never masquerade as a passing drift check there;
//! * `PIMDB_BLESS=1` — re-bless after an intentional change, then commit.
//!
//! The authoring environments of PRs 1–3 had no Rust toolchain, so the
//! files could not be generated there; the first `cargo test` run on a
//! real toolchain produces them and the warning says to commit them.
//! Independently of the snapshots, the test always asserts the rendering
//! is byte-identical between serial and 8-way parallel execution —
//! determinism and parallelism-independence are checked on every run.

use std::fs;
use std::path::PathBuf;

use pimdb::config::SystemConfig;
use pimdb::exec::pimdb::EngineKind;
use pimdb::query::opt::OptLevel;
use pimdb::report::{tables, Experiments};

fn render(parallelism: usize, opt_level: OptLevel) -> String {
    let cfg = SystemConfig {
        sim_sf: 0.002,
        parallelism,
        opt_level,
        ..SystemConfig::default()
    };
    let exps = Experiments::run(&cfg, EngineKind::Native).unwrap();
    format!(
        "{}\n{}",
        tables::table5_string(&exps),
        tables::table6_string(&exps)
    )
}

fn check_snapshot(rendered: &str, file: &str) {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(file);
    let blessing = std::env::var("PIMDB_BLESS").is_ok();
    if !blessing && path.exists() {
        let want = fs::read_to_string(&path).unwrap();
        assert_eq!(
            rendered, want,
            "table 5/6 snapshot {file} drifted; if intentional, re-bless \
             with PIMDB_BLESS=1 cargo test -q and commit the file"
        );
        return;
    }
    if !blessing && std::env::var("GITHUB_ACTIONS").is_ok() {
        panic!(
            "golden snapshot {} is missing in CI; CI never blesses its own \
             reference — generate it locally (cargo test -q) and commit it",
            path.display()
        );
    }
    fs::create_dir_all(path.parent().unwrap()).unwrap();
    fs::write(&path, rendered).unwrap();
    eprintln!(
        "WARNING: golden snapshot was missing; blessed {} from this run — \
         commit it, or the drift check guards nothing (CI refuses to run \
         with an uncommitted snapshot)",
        path.display()
    );
}

/// The original reference, pinned at -O0 (the naive compiler streams).
#[test]
fn tables_5_6_golden_snapshot_o0() {
    let serial = render(1, OptLevel::O0);
    let parallel = render(8, OptLevel::O0);
    assert_eq!(
        serial, parallel,
        "report tables must not depend on host parallelism"
    );
    check_snapshot(&serial, "tests/golden/tables_sf0.002_seed42.txt");
}

/// The -O2 default the engine executes.
#[test]
fn tables_5_6_golden_snapshot_o2() {
    let serial = render(1, OptLevel::O2);
    let parallel = render(8, OptLevel::O2);
    assert_eq!(
        serial, parallel,
        "report tables must not depend on host parallelism"
    );
    check_snapshot(&serial, "tests/golden/tables_sf0.002_seed42_O2.txt");
}
