//! Golden snapshot of the Table 5/6-style report output for a fixed
//! seed/scale, so report regressions are caught by `cargo test`.
//!
//! The snapshot lives at `tests/golden/tables_sf0.002_seed42.txt`.
//! Semantics (PR 2 removed the *silent* self-bless from PR 1):
//!
//! * snapshot present — rendered tables must match it byte-for-byte;
//! * snapshot missing, local run — the test blesses the file with a loud
//!   warning so the contributor commits it;
//! * snapshot missing in GitHub CI (`GITHUB_ACTIONS` set) — the test
//!   FAILS: CI may never bless its own reference. The workflow
//!   additionally refuses untracked files under `tests/golden/`, so a
//!   blessing run can never masquerade as a passing drift check there;
//! * `PIMDB_BLESS=1` — re-bless after an intentional change, then commit.
//!
//! The authoring environments of PR 1 and PR 2 had no Rust toolchain, so
//! the file could not be generated there; the first `cargo test` run on a
//! real toolchain produces it and the warning says to commit it.
//! Independently of the snapshot, the test always asserts the rendering
//! is byte-identical between serial and 8-way parallel execution —
//! determinism and parallelism-independence are checked on every run.

use std::fs;
use std::path::PathBuf;

use pimdb::config::SystemConfig;
use pimdb::exec::pimdb::EngineKind;
use pimdb::report::{tables, Experiments};

fn render(parallelism: usize) -> String {
    let cfg = SystemConfig {
        sim_sf: 0.002,
        parallelism,
        ..SystemConfig::default()
    };
    let exps = Experiments::run(&cfg, EngineKind::Native).unwrap();
    format!(
        "{}\n{}",
        tables::table5_string(&exps),
        tables::table6_string(&exps)
    )
}

#[test]
fn tables_5_6_golden_snapshot() {
    let serial = render(1);
    let parallel = render(8);
    assert_eq!(
        serial, parallel,
        "report tables must not depend on host parallelism"
    );

    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/tables_sf0.002_seed42.txt");
    let blessing = std::env::var("PIMDB_BLESS").is_ok();
    if !blessing && path.exists() {
        let want = fs::read_to_string(&path).unwrap();
        assert_eq!(
            serial, want,
            "table 5/6 snapshot drifted; if intentional, re-bless with \
             PIMDB_BLESS=1 cargo test -q and commit the file"
        );
        return;
    }
    if !blessing && std::env::var("GITHUB_ACTIONS").is_ok() {
        panic!(
            "golden snapshot {} is missing in CI; CI never blesses its own \
             reference — generate it locally (cargo test -q) and commit it",
            path.display()
        );
    }
    fs::create_dir_all(path.parent().unwrap()).unwrap();
    fs::write(&path, &serial).unwrap();
    eprintln!(
        "WARNING: golden snapshot was missing; blessed {} from this run — \
         commit it, or the drift check guards nothing (CI refuses to run \
         with an uncommitted snapshot)",
        path.display()
    );
}
