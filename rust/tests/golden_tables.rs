//! Golden snapshot of the Table 5/6-style report output for a fixed
//! seed/scale, so report regressions are caught by `cargo test`.
//!
//! The snapshot lives at `tests/golden/tables_sf0.002_seed42.txt`. On the
//! first run (or with `PIMDB_BLESS=1`) the test writes the snapshot and
//! passes; afterwards any drift in the rendered tables fails the test.
//!
//! IMPORTANT: the drift check is only binding once the blessed file is
//! **committed** — on a fresh checkout without it, the test self-blesses
//! and the snapshot guards nothing. The authoring environment for this
//! test had no Rust toolchain, so the file could not be generated here:
//! the first contributor with a toolchain should run `cargo test -q` and
//! commit the generated `tests/golden/` file. Independently of the
//! snapshot, the test always asserts the rendering is byte-identical
//! between two separate runs at serial and 8-way parallel execution —
//! determinism and parallelism-independence are checked on every run.

use std::fs;
use std::path::PathBuf;

use pimdb::config::SystemConfig;
use pimdb::exec::pimdb::EngineKind;
use pimdb::report::{tables, Experiments};

fn render(parallelism: usize) -> String {
    let cfg = SystemConfig {
        sim_sf: 0.002,
        parallelism,
        ..SystemConfig::default()
    };
    let exps = Experiments::run(&cfg, EngineKind::Native).unwrap();
    format!(
        "{}\n{}",
        tables::table5_string(&exps),
        tables::table6_string(&exps)
    )
}

#[test]
fn tables_5_6_golden_snapshot() {
    let serial = render(1);
    let parallel = render(8);
    assert_eq!(
        serial, parallel,
        "report tables must not depend on host parallelism"
    );

    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/tables_sf0.002_seed42.txt");
    if std::env::var("PIMDB_BLESS").is_ok() || !path.exists() {
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(&path, &serial).unwrap();
        eprintln!("blessed golden snapshot at {}", path.display());
    } else {
        let want = fs::read_to_string(&path).unwrap();
        assert_eq!(
            serial, want,
            "table 5/6 snapshot drifted; rerun with PIMDB_BLESS=1 to re-bless"
        );
    }
}
