//! The new service API (`api::Pimdb`, prepared statements, plan cache,
//! typed rows) pinned bit-for-bit against the original `PimSession` path.
//!
//! The facade is a re-plumbing of the same engine — same compiler, same
//! optimizer, same sharded executor, same simulation — so every TPC-H
//! query and every PQL fixture must produce *identical* outputs and
//! Table 5/6 metrics through both doors, and concurrent `execute(&self)`
//! from several threads must match the serial run exactly, at every
//! `parallelism`. This suite is the migration safety net; it outlives the
//! old path until `PimSession` is deleted.

use std::sync::Arc;

use pimdb::api::{Pimdb, QuerySource};
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::exec::metrics::{PlanCacheCounters, QueryMetrics, RunReport};
use pimdb::exec::pimdb::{EngineKind, PimSession};
use pimdb::query::lang::parse_program;
use pimdb::query::tpch;

const SIM_SF: f64 = 0.002;

/// The 19 evaluated queries as PQL text (same fixture set as
/// `pql_fixtures.rs`, which proves them node-for-node equal to the
/// hardcoded ASTs).
const PQL_FIXTURES: &[(&str, &str)] = &[
    ("Q1", include_str!("pql/q1.pql")),
    ("Q2", include_str!("pql/q2.pql")),
    ("Q3", include_str!("pql/q3.pql")),
    ("Q4", include_str!("pql/q4.pql")),
    ("Q5", include_str!("pql/q5.pql")),
    ("Q6", include_str!("pql/q6.pql")),
    ("Q7", include_str!("pql/q7.pql")),
    ("Q8", include_str!("pql/q8.pql")),
    ("Q10", include_str!("pql/q10.pql")),
    ("Q11", include_str!("pql/q11.pql")),
    ("Q12", include_str!("pql/q12.pql")),
    ("Q14", include_str!("pql/q14.pql")),
    ("Q15", include_str!("pql/q15.pql")),
    ("Q16", include_str!("pql/q16.pql")),
    ("Q17", include_str!("pql/q17.pql")),
    ("Q19", include_str!("pql/q19.pql")),
    ("Q20", include_str!("pql/q20.pql")),
    ("Q21", include_str!("pql/q21.pql")),
    ("Q22_sub", include_str!("pql/q22_sub.pql")),
];

fn db() -> Database {
    Database::generate(SIM_SF, 42)
}

/// Every simulated metric must be bit-identical between the two paths
/// (floats compare by bit pattern, not tolerance). `plan_cache` is the
/// one legitimate difference: the legacy path has no cache.
fn assert_reports_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.query, b.query, "{ctx}: query name");
    assert_eq!(a.output, b.output, "{ctx}: functional output");
    let (am, bm): (&QueryMetrics, &QueryMetrics) = (&a.metrics, &b.metrics);
    assert_eq!(am.cycles, bm.cycles, "{ctx}: cycle counts");
    assert_eq!(am.inter_cells, bm.inter_cells, "{ctx}: inter cells");
    assert_eq!(am.opt, bm.opt, "{ctx}: optimizer summary");
    assert_eq!(am.llc_misses, bm.llc_misses, "{ctx}: llc misses");
    assert_eq!(am.pim_energy, bm.pim_energy, "{ctx}: pim energy ledger");
    for (x, y, what) in [
        (am.exec_time_s, bm.exec_time_s, "exec_time_s"),
        (am.pim_time_s, bm.pim_time_s, "pim_time_s"),
        (am.read_time_s, bm.read_time_s, "read_time_s"),
        (am.other_time_s, bm.other_time_s, "other_time_s"),
        (am.host_energy_pj, bm.host_energy_pj, "host_energy_pj"),
        (am.dram_energy_pj, bm.dram_energy_pj, "dram_energy_pj"),
        (am.peak_chip_w, bm.peak_chip_w, "peak_chip_w"),
        (am.avg_chip_w, bm.avg_chip_w, "avg_chip_w"),
        (
            am.theoretical_chip_w,
            bm.theoretical_chip_w,
            "theoretical_chip_w",
        ),
        (am.ops_per_cell, bm.ops_per_cell, "ops_per_cell"),
        (
            am.required_endurance_10yr,
            bm.required_endurance_10yr,
            "required_endurance_10yr",
        ),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {what}");
    }
    for i in 0..5 {
        assert_eq!(
            am.endurance_breakdown[i].to_bits(),
            bm.endurance_breakdown[i].to_bits(),
            "{ctx}: endurance_breakdown[{i}]"
        );
    }
}

/// All 19 TPC-H queries: `Pimdb::prepare`/`execute` vs the legacy
/// session, outputs and Table 5/6 metrics bit-identical.
#[test]
fn all_tpch_queries_match_the_legacy_session() {
    let cfg = SystemConfig {
        sim_sf: SIM_SF,
        ..SystemConfig::default()
    };
    let data = db();
    let mut legacy = PimSession::new(&cfg, &data).unwrap();
    let handle = Pimdb::open(cfg.clone(), db()).unwrap();
    for q in tpch::all_queries() {
        let want = legacy.run_query(&q, EngineKind::Native).unwrap();
        let got = handle
            .prepare(QuerySource::Ast(&q))
            .unwrap()
            .execute()
            .unwrap();
        assert_reports_identical(got.raw_report(), &want, q.name);
    }
}

/// Every PQL fixture, prepared as *text* (the parse->cache-key->compile
/// path), matches the legacy session running the same program.
#[test]
fn pql_fixtures_match_the_legacy_session() {
    let cfg = SystemConfig {
        sim_sf: SIM_SF,
        ..SystemConfig::default()
    };
    let data = db();
    let mut legacy = PimSession::new(&cfg, &data).unwrap();
    let handle = Pimdb::open(cfg.clone(), db()).unwrap();
    for &(name, src) in PQL_FIXTURES {
        let queries = parse_program(src).unwrap_or_else(|e| panic!("{name}: {}", e.render(src)));
        let want = legacy
            .run_queries(&queries, EngineKind::Native)
            .unwrap()
            .pop()
            .unwrap();
        let got = handle.prepare(src).unwrap().execute().unwrap();
        assert_reports_identical(got.raw_report(), &want, name);
    }
    // one compilation per distinct fixture — nothing double-compiled
    let c = handle.plan_cache_counters();
    assert_eq!(c.misses, PQL_FIXTURES.len() as u64, "one compile each");
    assert_eq!(c.hits, 0);
}

/// Concurrent `execute` from `&self` over shared statements matches the
/// serial legacy run bit-for-bit at every shard-pool width.
#[test]
fn concurrent_prepared_execution_is_bit_identical_at_every_parallelism() {
    let base_cfg = SystemConfig {
        sim_sf: SIM_SF,
        ..SystemConfig::default()
    };
    let data = db();
    let mut legacy = PimSession::new(&base_cfg, &data).unwrap();
    // mixed workload: disjoint relations (parallel) + a shared relation
    // (serializes on its lock) + a full query
    let names = ["Q6", "Q11", "Q1", "Q12", "Q22_sub"];
    let want: Vec<RunReport> = names
        .iter()
        .map(|n| {
            legacy
                .run_query(&tpch::query(n).unwrap(), EngineKind::Native)
                .unwrap()
        })
        .collect();

    for workers in [1usize, 2, 8] {
        let cfg = SystemConfig {
            parallelism: workers,
            ..base_cfg.clone()
        };
        let handle = Arc::new(Pimdb::open(cfg, db()).unwrap());
        let stmts: Vec<_> = names
            .iter()
            .map(|n| handle.prepare(QuerySource::Tpch(n)).unwrap())
            .collect();
        // two full rounds in flight at once: every statement executes
        // concurrently with itself and with the others
        std::thread::scope(|s| {
            let round = |tag: usize| {
                let stmts = &stmts;
                move || {
                    stmts
                        .iter()
                        .map(|st| (tag, st.execute().unwrap()))
                        .collect::<Vec<_>>()
                }
            };
            let t1 = s.spawn(round(1));
            let t2 = s.spawn(round(2));
            for results in [t1.join().unwrap(), t2.join().unwrap()] {
                for ((_, got), want) in results.iter().zip(&want) {
                    assert_reports_identical(
                        got.raw_report(),
                        want,
                        &format!("{} at parallelism {workers}", want.query),
                    );
                }
            }
        });
    }
}

/// The satellite contract: preparing the same PQL text twice compiles
/// once; whitespace and alias renames hit; literals miss.
#[test]
fn plan_cache_amortizes_repeated_templates() {
    let cfg = SystemConfig {
        sim_sf: SIM_SF,
        ..SystemConfig::default()
    };
    let handle = Pimdb::open(cfg, db()).unwrap();
    let q6 = include_str!("pql/q6.pql");
    handle.prepare(q6).unwrap();
    handle.prepare(q6).unwrap();
    assert_eq!(
        handle.plan_cache_counters(),
        PlanCacheCounters { hits: 1, misses: 1 }
    );
    // reformatted + renamed + re-aliased: still the same template
    let reformatted = "query Q6_again from lineitem | filter \
        (l_shipdate >= date(1994-01-01) and l_shipdate < date(1995-01-01)) \
        and l_discount between 0.05..0.07 and l_quantity < 24 \
        | aggregate sum(l_extendedprice * l_discount) as rev";
    let stmt = handle.prepare(reformatted).unwrap();
    assert_eq!(
        handle.plan_cache_counters(),
        PlanCacheCounters { hits: 2, misses: 1 }
    );
    // the hit still executes under its own alias and name
    let r = stmt.execute().unwrap();
    assert_eq!(r.query_name(), "Q6_again");
    assert!(r.rows().row(0).unwrap().get("rev").is_some());
    // a changed literal is a different plan
    let changed = "from lineitem | filter \
        (l_shipdate >= date(1994-01-01) and l_shipdate < date(1995-01-01)) \
        and l_discount between 0.05..0.07 and l_quantity < 25 \
        | aggregate sum(l_extendedprice * l_discount) as rev";
    handle.prepare(changed).unwrap();
    assert_eq!(
        handle.plan_cache_counters(),
        PlanCacheCounters { hits: 2, misses: 2 }
    );
}

/// Typed rows decode what the raw output encodes, on a real query: Q1's
/// group keys are dictionary words, Q6's revenue is numeric, filter-only
/// queries report per-relation selection counts.
#[test]
fn typed_rows_decode_real_query_results() {
    let cfg = SystemConfig {
        sim_sf: SIM_SF,
        ..SystemConfig::default()
    };
    let handle = Pimdb::open(cfg, db()).unwrap();

    let q1 = handle.prepare(QuerySource::Tpch("Q1")).unwrap().execute().unwrap();
    let raw = &q1.raw_report().output;
    assert_eq!(q1.rows().len(), raw.groups.len());
    for (row, group) in q1.rows().zip(&raw.groups) {
        // dictionary-decoded keys: returnflag in {R,A,N}, linestatus in {O,F}
        let flag = row.get("l_returnflag").unwrap().as_str().unwrap();
        assert!(["R", "A", "N"].contains(&flag), "{flag}");
        let status = row.get("l_linestatus").unwrap().as_str().unwrap();
        assert!(["O", "F"].contains(&status), "{status}");
        assert_eq!(
            row.get("count").unwrap().as_i64().unwrap() as u64,
            group.count
        );
    }

    let q12 = handle.prepare(QuerySource::Tpch("Q12")).unwrap().execute().unwrap();
    let row0 = q12.rows().row(0).unwrap().clone();
    assert_eq!(row0.get("relation").unwrap().as_str(), Some("LINEITEM"));
    assert_eq!(
        row0.get("selected").unwrap().as_i64().unwrap() as u64,
        q12.raw_report().output.selected[0].1
    );
}

/// `Pimdb` is an owned handle: it must stay `Send + Sync` (the old
/// `PimSession<'a>` required external `&mut` serialization and borrowed
/// its inputs — the compile-time assertion pins the new ownership model).
#[test]
fn handle_is_send_sync_and_arc_shareable() {
    fn takes_send_sync<T: Send + Sync + 'static>(_: &T) {}
    let handle = Pimdb::open(
        SystemConfig {
            sim_sf: SIM_SF,
            ..SystemConfig::default()
        },
        db(),
    )
    .unwrap();
    takes_send_sync(&handle);
    let shared = Arc::new(handle);
    let clone = Arc::clone(&shared);
    let t = std::thread::spawn(move || {
        clone
            .prepare("from supplier | filter s_suppkey < 10")
            .unwrap()
            .execute()
            .unwrap()
            .rows()
            .len()
    });
    assert_eq!(t.join().unwrap(), 1);
}
