//! Durability fault-injection battery: crash-point sweep, bit-rot
//! refusal, and checkpoint+replay equivalence.
//!
//! The contract under test (see `ARCHITECTURE.md`, *Durability and
//! recovery*): a durable handle reopened from its data directory is
//! **bit-identical** to one that never closed — same live records, same
//! committed wear counters, same epochs, same 19-query TPC-H outputs —
//! no matter where a crash cut the write-ahead log, as long as the cut
//! is pure truncation. Torn tails land on the previous batch boundary
//! (all-or-nothing per batch); *damaged* bytes (bit rot) are refused
//! with a typed [`PimdbError::Corrupt`], never silently dropped.
//!
//! The WAL frame layout is re-derived here from the documented format
//! (magic + fingerprint header, then `len u32 | checksum u64 | payload`
//! frames) rather than importing the crate's own scanner — the test is
//! an independent oracle of the on-disk contract.

use std::fs;
use std::path::{Path, PathBuf};

use pimdb::api::Pimdb;
use pimdb::config::{DurabilityConfig, FsyncPolicy, SystemConfig};
use pimdb::db::dbgen::Database;
use pimdb::db::schema::{RelId, PIM_RELATIONS};
use pimdb::error::PimdbError;
use pimdb::query::tpch;

const SEED: u64 = 42;

/// One statement per group-commit batch (the calls are serial), so WAL
/// record `k` is exactly statement `k`. Mixed kinds over four relations:
/// deletes, in-place updates, and wear-ranked inserts.
const BATCHES: &[&str] = &[
    "delete from supplier where s_suppkey <= 3",
    "update part set p_size = 15 where p_size == 14",
    "insert into supplier (s_suppkey, s_nationkey, s_acctbal) values (10001, 7, 1000.00)",
    "delete from lineitem where l_quantity >= 49",
    "update orders set o_shippriority = 1 where o_orderstatus == \"F\"",
    "insert into supplier (s_suppkey, s_nationkey, s_acctbal) values (10002, 3, 250.50)",
];

fn cfg() -> SystemConfig {
    SystemConfig {
        sim_sf: 0.001,
        ..SystemConfig::default()
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "pimdb-recovery-{tag}-{}",
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn dcfg(dir: &Path, fsync: FsyncPolicy) -> DurabilityConfig {
    DurabilityConfig {
        fsync,
        ..DurabilityConfig::new(dir)
    }
}

/// An in-memory oracle handle with the first `k` batches applied.
fn oracle_after(k: usize) -> Pimdb {
    let c = cfg();
    let handle = Pimdb::open(c.clone(), Database::generate(c.sim_sf, SEED)).unwrap();
    for src in &BATCHES[..k] {
        handle.execute_dml(*src).unwrap();
    }
    handle
}

/// Everything cheap that must be bit-identical after recovery: live
/// records, epoch, and the full per-row wear counters of every relation.
fn state_digest(h: &Pimdb) -> Vec<(RelId, usize, u64, Vec<u64>)> {
    PIM_RELATIONS
        .iter()
        .map(|&r| {
            (
                r,
                h.live_records(r),
                h.relation_epoch(r),
                h.wear_counters(r),
            )
        })
        .collect()
}

/// The expensive equivalence: all 19 evaluated TPC-H queries produce the
/// same output on both handles.
fn assert_query_sweep_eq(a: &Pimdb, b: &Pimdb, what: &str) {
    for q in tpch::all_queries() {
        let ra = a.prepare(&q).unwrap().execute().unwrap();
        let rb = b.prepare(&q).unwrap().execute().unwrap();
        assert_eq!(
            ra.raw_report().output,
            rb.raw_report().output,
            "{}: {what}",
            q.name
        );
    }
}

fn copy_dir(src: &Path, dst: &Path) {
    fs::create_dir_all(dst).unwrap();
    for entry in fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
}

fn wal0(dir: &Path) -> PathBuf {
    dir.join("wal-00000000.log")
}

/// Re-derive the record boundaries of a WAL image from the documented
/// frame layout (independent of the crate's scanner).
fn record_boundaries(wal: &[u8]) -> Vec<usize> {
    let mut bounds = vec![16];
    let mut off = 16;
    while wal.len() - off >= 12 {
        let len = u32::from_le_bytes(wal[off..off + 4].try_into().unwrap()) as usize;
        if wal.len() - off - 12 < len {
            break;
        }
        off += 12 + len;
        bounds.push(off);
    }
    bounds
}

/// Populate `dir` with all `BATCHES` and simulate a crash (drop the
/// handle without a checkpoint). Returns the WAL image.
fn populate_and_crash(dir: &Path, fsync: FsyncPolicy) -> Vec<u8> {
    let handle = Pimdb::open_durable(cfg(), dcfg(dir, fsync)).unwrap();
    for src in BATCHES {
        handle.execute_dml(*src).unwrap();
    }
    let stats = handle.durability_stats().unwrap();
    assert_eq!(stats.wal_records_appended, BATCHES.len() as u64);
    assert!(stats.wal_bytes_appended > 0);
    drop(handle);
    fs::read(wal0(dir)).unwrap()
}

#[test]
fn crash_point_sweep_recovers_exactly_the_batch_prefix() {
    let dir = tmpdir("sweep");
    let wal = populate_and_crash(&dir, FsyncPolicy::Off);
    let bounds = record_boundaries(&wal);
    assert_eq!(bounds.len(), BATCHES.len() + 1, "one record per batch");

    // the oracle chain: state digests after 0..=n batches
    let oracles: Vec<_> = (0..=BATCHES.len())
        .map(|k| state_digest(&oracle_after(k)))
        .collect();

    // every record boundary, plus every byte offset inside the tail
    // record, plus a cut inside the header
    let mut cuts: Vec<usize> = vec![0, 7];
    cuts.extend(bounds.iter().copied());
    cuts.extend(bounds[BATCHES.len() - 1] + 1..bounds[BATCHES.len()]);

    for cut in cuts {
        let case = tmpdir("sweep-case");
        copy_dir(&dir, &case);
        let f = fs::OpenOptions::new()
            .write(true)
            .open(wal0(&case))
            .unwrap();
        f.set_len(cut as u64).unwrap();
        drop(f);

        let recovered = Pimdb::open_durable(cfg(), dcfg(&case, FsyncPolicy::Off)).unwrap();
        // number of complete records surviving the cut
        let k = if cut < 16 {
            0
        } else {
            bounds.iter().filter(|&&b| b <= cut).count() - 1
        };
        assert_eq!(
            state_digest(&recovered),
            oracles[k],
            "cut at byte {cut} must recover exactly {k} batches"
        );
        let stats = recovered.durability_stats().unwrap();
        assert_eq!(stats.wal_records_replayed, k as u64, "cut {cut}");
        let torn = cut < 16 || !bounds.contains(&cut);
        assert_eq!(stats.torn_tails_truncated, u64::from(torn), "cut {cut}");
        drop(recovered);

        // truncation is idempotent: the torn tail was cut back to the
        // boundary on disk, so a second recovery sees a clean log
        let again = Pimdb::open_durable(cfg(), dcfg(&case, FsyncPolicy::Off)).unwrap();
        assert_eq!(state_digest(&again), oracles[k], "re-open after cut {cut}");
        let stats = again.durability_stats().unwrap();
        assert_eq!(stats.torn_tails_truncated, 0, "cut {cut} second open");
        let _ = fs::remove_dir_all(&case);
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn recovered_handle_matches_the_never_closed_oracle_on_the_query_sweep() {
    let dir = tmpdir("sweep-queries");
    populate_and_crash(&dir, FsyncPolicy::GroupCommit);
    let recovered = Pimdb::open_durable(cfg(), dcfg(&dir, FsyncPolicy::GroupCommit)).unwrap();
    let oracle = oracle_after(BATCHES.len());
    assert_eq!(state_digest(&recovered), state_digest(&oracle));
    assert_query_sweep_eq(&recovered, &oracle, "full replay vs never-closed");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn bit_rot_in_a_complete_record_is_refused_not_truncated() {
    let dir = tmpdir("bitrot");
    let wal = populate_and_crash(&dir, FsyncPolicy::Off);
    let bounds = record_boundaries(&wal);

    // flip one payload byte inside the *first* record: the frame is
    // complete, so this must be Corrupt — recovery must not quietly
    // truncate five committed batches away
    let mut flipped = wal.clone();
    flipped[bounds[0] + 12 + 3] ^= 0x10;
    fs::write(wal0(&dir), &flipped).unwrap();
    match Pimdb::open_durable(cfg(), dcfg(&dir, FsyncPolicy::Off)) {
        Err(PimdbError::Corrupt(msg)) => {
            assert!(msg.contains("checksum"), "unexpected message: {msg}")
        }
        other => panic!("expected Corrupt, got {other:?}"),
    }

    // a flipped header fingerprint refuses the whole segment
    let mut bad_fp = wal.clone();
    bad_fp[8] ^= 1;
    fs::write(wal0(&dir), &bad_fp).unwrap();
    assert!(matches!(
        Pimdb::open_durable(cfg(), dcfg(&dir, FsyncPolicy::Off)),
        Err(PimdbError::Corrupt(_))
    ));

    // bit rot in the base image is refused by its whole-file digest
    fs::write(wal0(&dir), &wal).unwrap();
    let base = dir.join("base.img");
    let mut img = fs::read(&base).unwrap();
    let mid = img.len() / 2;
    img[mid] ^= 1;
    fs::write(&base, &img).unwrap();
    assert!(matches!(
        Pimdb::open_durable(cfg(), dcfg(&dir, FsyncPolicy::Off)),
        Err(PimdbError::Corrupt(_))
    ));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_plus_replay_equals_replay_only_and_the_live_oracle() {
    // handle A: checkpoint midway, crash after the rest
    let dir_a = tmpdir("ckpt-a");
    {
        let handle = Pimdb::open_durable(cfg(), dcfg(&dir_a, FsyncPolicy::GroupCommit)).unwrap();
        for src in &BATCHES[..3] {
            handle.execute_dml(*src).unwrap();
        }
        let bytes = handle.checkpoint().unwrap();
        assert!(bytes > 0);
        for src in &BATCHES[3..] {
            handle.execute_dml(*src).unwrap();
        }
        let stats = handle.durability_stats().unwrap();
        assert_eq!(stats.checkpoints_written, 1);
        assert!(stats.last_checkpoint_epoch > 0);
        // the checkpoint rotated the log: generation 1 exists now
        assert!(dir_a.join("ckpt-00000001.pim").exists());
        assert!(dir_a.join("wal-00000001.log").exists());
    }
    // handle B: same batches, no checkpoint — replay-only recovery
    let dir_b = tmpdir("ckpt-b");
    populate_and_crash(&dir_b, FsyncPolicy::GroupCommit);

    let a = Pimdb::open_durable(cfg(), dcfg(&dir_a, FsyncPolicy::GroupCommit)).unwrap();
    let b = Pimdb::open_durable(cfg(), dcfg(&dir_b, FsyncPolicy::GroupCommit)).unwrap();
    let oracle = oracle_after(BATCHES.len());

    // A replayed only the post-checkpoint suffix, B replayed everything
    assert_eq!(a.durability_stats().unwrap().wal_records_replayed, 3);
    assert_eq!(
        b.durability_stats().unwrap().wal_records_replayed,
        BATCHES.len() as u64
    );
    assert_eq!(state_digest(&a), state_digest(&oracle));
    assert_eq!(state_digest(&b), state_digest(&oracle));
    assert_query_sweep_eq(&a, &oracle, "checkpoint+replay vs never-closed");
    assert_query_sweep_eq(&a, &b, "checkpoint+replay vs replay-only");
    let _ = fs::remove_dir_all(&dir_a);
    let _ = fs::remove_dir_all(&dir_b);
}

#[test]
fn corrupt_newest_checkpoint_falls_back_and_still_recovers_everything() {
    let dir = tmpdir("ckpt-fallback");
    {
        let handle = Pimdb::open_durable(cfg(), dcfg(&dir, FsyncPolicy::GroupCommit)).unwrap();
        for src in &BATCHES[..4] {
            handle.execute_dml(*src).unwrap();
        }
        handle.checkpoint().unwrap();
        for src in &BATCHES[4..] {
            handle.execute_dml(*src).unwrap();
        }
    }
    // rot the generation-1 checkpoint: recovery must fall back to the
    // generation-0 (empty) checkpoint and replay wal-0 *and* wal-1
    let ckpt = dir.join("ckpt-00000001.pim");
    let mut img = fs::read(&ckpt).unwrap();
    let mid = img.len() / 2;
    img[mid] ^= 1;
    fs::write(&ckpt, &img).unwrap();

    let recovered = Pimdb::open_durable(cfg(), dcfg(&dir, FsyncPolicy::GroupCommit)).unwrap();
    let stats = recovered.durability_stats().unwrap();
    assert_eq!(stats.checkpoints_skipped, 1);
    assert_eq!(stats.wal_records_replayed, BATCHES.len() as u64);
    let oracle = oracle_after(BATCHES.len());
    assert_eq!(state_digest(&recovered), state_digest(&oracle));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn second_checkpoint_prunes_old_generations_but_keeps_the_fallback() {
    let dir = tmpdir("prune");
    let handle = Pimdb::open_durable(cfg(), dcfg(&dir, FsyncPolicy::Off)).unwrap();
    handle.execute_dml(BATCHES[0]).unwrap();
    handle.checkpoint().unwrap(); // generation 1
    handle.execute_dml(BATCHES[2]).unwrap();
    handle.checkpoint().unwrap(); // generation 2: prunes generation 0
    assert!(!dir.join("ckpt-00000000.pim").exists());
    assert!(!wal0(&dir).exists());
    assert!(dir.join("ckpt-00000001.pim").exists(), "fallback stays");
    assert!(dir.join("ckpt-00000002.pim").exists());
    drop(handle);

    let recovered = Pimdb::open_durable(cfg(), dcfg(&dir, FsyncPolicy::Off)).unwrap();
    let oracle = Pimdb::open(cfg(), Database::generate(0.001, SEED)).unwrap();
    oracle.execute_dml(BATCHES[0]).unwrap();
    oracle.execute_dml(BATCHES[2]).unwrap();
    assert_eq!(state_digest(&recovered), state_digest(&oracle));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn fsync_policies_and_config_guards() {
    // every fsync policy produces the same recoverable log
    for (tag, fsync) in [
        ("fs-always", FsyncPolicy::Always),
        ("fs-group", FsyncPolicy::GroupCommit),
        ("fs-off", FsyncPolicy::Off),
    ] {
        let dir = tmpdir(tag);
        {
            let handle = Pimdb::open_durable(cfg(), dcfg(&dir, fsync)).unwrap();
            handle.execute_dml(BATCHES[0]).unwrap();
        }
        let recovered = Pimdb::open_durable(cfg(), dcfg(&dir, fsync)).unwrap();
        assert_eq!(state_digest(&recovered), state_digest(&oracle_after(1)));
        let _ = fs::remove_dir_all(&dir);
    }

    // reopening at a different scale factor is a Config error
    let dir = tmpdir("sf-guard");
    drop(Pimdb::open_durable(cfg(), dcfg(&dir, FsyncPolicy::Off)).unwrap());
    let other = SystemConfig {
        sim_sf: 0.002,
        ..cfg()
    };
    assert!(matches!(
        Pimdb::open_durable(other, dcfg(&dir, FsyncPolicy::Off)),
        Err(PimdbError::Config(_))
    ));

    // checkpoint and stats require a durable handle
    let mem = Pimdb::open(cfg(), Database::generate(0.001, SEED)).unwrap();
    assert!(matches!(mem.checkpoint(), Err(PimdbError::Config(_))));
    assert!(mem.durability_stats().is_none());
    let _ = fs::remove_dir_all(&dir);
}
