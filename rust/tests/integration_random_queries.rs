//! Randomized query fuzzing: generate random filter predicates and
//! aggregates over random relations, compile them, execute on the PIMDB
//! engine, and check against the baseline oracle. This exercises the
//! compiler's column allocator, every comparison lowering (incl. Le/Ge
//! boundary rewrites), IN-set expansion, nested Not/Or, and the masked
//! aggregation pipeline far beyond the 19 fixed TPC-H queries.

use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::db::schema::{self, RelId};
use pimdb::exec::{baseline, pimdb as engine};
use pimdb::query::ast::*;
use pimdb::util::proptest::{check, Gen};

fn rand_attr(g: &mut Gen, rel: RelId) -> (&'static str, usize) {
    let attrs = schema::attrs(rel);
    let a = attrs[g.usize(0, attrs.len() - 1)];
    (a.name, a.bits)
}

fn rand_value(g: &mut Gen, bits: usize) -> u64 {
    // cluster around the interesting part of the domain
    let max = if bits >= 64 { u64::MAX } else { (1 << bits) - 1 };
    g.u64(0, max.min(1 << bits.min(40)))
}

fn rand_pred(g: &mut Gen, rel: RelId, depth: usize) -> Pred {
    if depth == 0 || g.u64(0, 3) == 0 {
        let (attr, bits) = rand_attr(g, rel);
        match g.u64(0, 3) {
            0 => {
                let ops = [CmpOp::Eq, CmpOp::Ne, CmpOp::Lt, CmpOp::Le, CmpOp::Gt, CmpOp::Ge];
                Pred::CmpImm {
                    attr,
                    op: *g.pick(&ops),
                    value: rand_value(g, bits),
                }
            }
            1 => Pred::InSet {
                attr,
                values: (0..g.usize(1, 4)).map(|_| rand_value(g, bits)).collect(),
            },
            2 => {
                let a = rand_value(g, bits);
                let b = rand_value(g, bits);
                Pred::Between {
                    attr,
                    lo: a.min(b),
                    hi: a.max(b),
                }
            }
            _ => {
                // two-column compare needs equal widths: dates on LINEITEM
                if rel == RelId::Lineitem {
                    Pred::CmpCols {
                        a: "l_commitdate",
                        op: *g.pick(&[CmpOp::Lt, CmpOp::Le, CmpOp::Gt, CmpOp::Ge, CmpOp::Eq]),
                        b: "l_receiptdate",
                    }
                } else {
                    Pred::CmpImm {
                        attr,
                        op: CmpOp::Ge,
                        value: rand_value(g, bits),
                    }
                }
            }
        }
    } else {
        let n = g.usize(1, 3);
        let subs: Vec<Pred> = (0..n).map(|_| rand_pred(g, rel, depth - 1)).collect();
        match g.u64(0, 2) {
            0 => Pred::And(subs),
            1 => Pred::Or(subs),
            _ => Pred::Not(Box::new(rand_pred(g, rel, depth - 1))),
        }
    }
}

#[test]
fn random_filters_match_oracle() {
    // the default config runs the -O2 optimizer pipeline, so every case
    // also differential-tests the passes against -O0 and the baseline
    let cfg = SystemConfig::default();
    let cfg_o0 = SystemConfig {
        opt_level: pimdb::query::opt::OptLevel::O0,
        ..SystemConfig::default()
    };
    let db = Database::generate(0.001, 77);
    let rels = [
        RelId::Lineitem,
        RelId::Orders,
        RelId::Part,
        RelId::Customer,
        RelId::Supplier,
        RelId::Partsupp,
    ];
    check("random-filters", 40, |g| {
        let rel = *g.pick(&rels);
        let q = Query {
            name: "fuzz",
            kind: QueryKind::FilterOnly,
            rels: vec![RelQuery {
                rel,
                filter: rand_pred(g, rel, 2),
                group_by: vec![],
                aggregates: vec![],
            }],
        };
        let pim = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native)
            .expect("compile+run");
        let base = baseline::run_query(&cfg, &db, &q);
        assert_eq!(pim.output, base.output, "filter {:?}", q.rels[0].filter);
        let unopt = engine::run_query(&cfg_o0, &db, &q, engine::EngineKind::Native)
            .expect("compile+run at -O0");
        assert_eq!(pim.output, unopt.output, "-O2 drift on {:?}", q.rels[0].filter);
        assert!(
            pim.metrics.cycles.total() <= unopt.metrics.cycles.total(),
            "-O2 cycles grew on {:?}",
            q.rels[0].filter
        );
    });
}

#[test]
fn random_aggregates_match_oracle() {
    let cfg = SystemConfig::default();
    let cfg_o0 = SystemConfig {
        opt_level: pimdb::query::opt::OptLevel::O0,
        ..SystemConfig::default()
    };
    let db = Database::generate(0.001, 78);
    check("random-aggregates", 25, |g| {
        let rel = *g.pick(&[RelId::Lineitem, RelId::Partsupp, RelId::Customer]);
        let (attr, _) = rand_attr(g, rel);
        let kinds = [AggKind::Sum, AggKind::Count, AggKind::Min, AggKind::Max, AggKind::Avg];
        let aggregates = vec![
            Aggregate {
                kind: *g.pick(&kinds),
                expr: ValExpr::Attr(attr),
                label: "agg0",
            },
            Aggregate {
                kind: AggKind::Count,
                expr: ValExpr::One,
                label: "cnt",
            },
        ];
        let q = Query {
            name: "fuzz_agg",
            kind: QueryKind::Full,
            rels: vec![RelQuery {
                rel,
                filter: rand_pred(g, rel, 1),
                group_by: vec![],
                aggregates,
            }],
        };
        let pim = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native)
            .expect("compile+run");
        let base = baseline::run_query(&cfg, &db, &q);
        // float-compare MIN/MAX/AVG via the structured output equality
        assert_eq!(
            pim.output, base.output,
            "filter {:?} aggs {:?}",
            q.rels[0].filter, q.rels[0].aggregates
        );
        let unopt = engine::run_query(&cfg_o0, &db, &q, engine::EngineKind::Native)
            .expect("compile+run at -O0");
        assert_eq!(
            pim.output, unopt.output,
            "-O2 drift: filter {:?} aggs {:?}",
            q.rels[0].filter, q.rels[0].aggregates
        );
    });
}

#[test]
fn random_queries_bit_identical_at_o0_o2_and_1_2_8_workers() {
    // the u64 word kernels must be bit-identical across opt level and
    // every worker count: same reduce streams, same mask counts, same
    // structured output (the shard merge restores serial crossbar order)
    let db = Database::generate(0.001, 79);
    check("random-workers", 12, |g| {
        let rel = *g.pick(&[RelId::Lineitem, RelId::Supplier, RelId::Orders]);
        let (attr, _) = rand_attr(g, rel);
        let aggregates = if g.u64(0, 1) == 0 {
            vec![
                Aggregate {
                    kind: AggKind::Sum,
                    expr: ValExpr::Attr(attr),
                    label: "s",
                },
                Aggregate {
                    kind: AggKind::Count,
                    expr: ValExpr::One,
                    label: "n",
                },
            ]
        } else {
            vec![]
        };
        let kind = if aggregates.is_empty() {
            QueryKind::FilterOnly
        } else {
            QueryKind::Full
        };
        let q = Query {
            name: "fuzz_workers",
            kind,
            rels: vec![RelQuery {
                rel,
                filter: rand_pred(g, rel, 2),
                group_by: vec![],
                aggregates,
            }],
        };
        let mut want = None;
        for level in [
            pimdb::query::opt::OptLevel::O0,
            pimdb::query::opt::OptLevel::O2,
        ] {
            for p in [1usize, 2, 8] {
                let cfg = SystemConfig {
                    opt_level: level,
                    parallelism: p,
                    ..SystemConfig::default()
                };
                let r = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native)
                    .expect("compile+run");
                match &want {
                    None => want = Some(r.output),
                    Some(w) => assert_eq!(
                        w,
                        &r.output,
                        "drift at -{level} p={p} on {:?}",
                        q.rels[0].filter
                    ),
                }
            }
        }
    });
}

// --- failure injection -------------------------------------------------------

#[test]
fn unknown_attribute_is_a_compile_error_not_a_panic() {
    let cfg = SystemConfig::default();
    let db = Database::generate(0.001, 1);
    let q = Query {
        name: "bad",
        kind: QueryKind::FilterOnly,
        rels: vec![RelQuery {
            rel: RelId::Part,
            filter: Pred::CmpImm {
                attr: "p_no_such_column",
                op: CmpOp::Eq,
                value: 1,
            },
            group_by: vec![],
            aggregates: vec![],
        }],
    };
    let err = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native).unwrap_err();
    assert!(
        matches!(err, pimdb::error::PimdbError::Compile(_)),
        "{err:?}"
    );
    assert!(err.to_string().contains("no attribute"), "{err}");
}

#[test]
fn mismatched_column_compare_widths_rejected() {
    let cfg = SystemConfig::default();
    let db = Database::generate(0.001, 1);
    let q = Query {
        name: "bad2",
        kind: QueryKind::FilterOnly,
        rels: vec![RelQuery {
            rel: RelId::Lineitem,
            filter: Pred::CmpCols {
                a: "l_quantity", // 6 bits
                op: CmpOp::Lt,
                b: "l_extendedprice", // 24 bits
            },
            group_by: vec![],
            aggregates: vec![],
        }],
    };
    let err = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native).unwrap_err();
    assert!(err.to_string().contains("widths differ"), "{err}");
}

#[test]
fn giant_in_set_exhausts_compute_area_gracefully() {
    // thousands of OR terms still fit (1 scratch column is reused), but a
    // pathological conjunction of hundreds of distinct Between subtrees
    // must fail with a compute-area error, not corrupt state
    let cfg = SystemConfig::default();
    let db = Database::generate(0.001, 1);
    let huge = Pred::InSet {
        attr: "p_size",
        values: (0..200).collect(),
    };
    let q = Query {
        name: "huge_inset",
        kind: QueryKind::FilterOnly,
        rels: vec![RelQuery {
            rel: RelId::Part,
            filter: huge,
            group_by: vec![],
            aggregates: vec![],
        }],
    };
    // IN-set reuses one scratch column -> must succeed
    let r = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native).unwrap();
    // p_size in 1..=50, so a 0..200 set selects everything
    assert_eq!(r.output.selected[0].1, db.rel(RelId::Part).records as u64);
}

#[test]
fn pim_capacity_exhaustion_is_an_error() {
    let mut cfg = SystemConfig::default();
    cfg.pim_modules = 1;
    cfg.module_capacity = 2 << 30; // 2 pages only: LINEITEM needs 358
    let db = Database::generate(0.001, 1);
    let q = pimdb::query::tpch::query("Q6").unwrap();
    let err = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native).unwrap_err();
    assert!(
        matches!(err, pimdb::error::PimdbError::Layout(_)),
        "{err:?}"
    );
    assert!(err.to_string().contains("exhausted"), "{err}");
}
