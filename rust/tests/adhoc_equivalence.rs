//! `run --sql` acceptance: ad-hoc text queries that were never hardcoded
//! anywhere must execute on both the PIM engine and the column-store
//! baseline with identical functional results, and agree with the scalar
//! oracle. This is the exact code path `pimdb run --sql "..."` drives.

use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::db::schema::RelId;
use pimdb::exec::pimdb::{EngineKind, PimSession};
use pimdb::exec::baseline;
use pimdb::query::lang::parse_program;

/// A SUPPLIER filter + aggregate combination that exists in no TPC-H
/// query: money threshold AND (region fold OR dictionary IN-set) AND a
/// negated key range, reduced three ways.
const ADHOC_SUPPLIER: &str = r#"
from supplier
| filter s_acctbal > 912.00
    and (s_nationkey in region("AFRICA") or s_phone_cc in (20, 25))
    and not s_suppkey < 3
| aggregate count() as suppliers, sum(s_acctbal) as sum_bal, avg(s_acctbal) as avg_bal
"#;

/// A grouped CUSTOMER aggregate (group key never used by the paper set).
const ADHOC_CUSTOMER: &str = r#"
from customer
| filter c_acctbal > 0.00
| group by c_mktsegment
| aggregate count() as customers, avg(c_acctbal) as avg_bal
"#;

#[test]
fn adhoc_supplier_query_matches_baseline_and_oracle() {
    let cfg = SystemConfig::default();
    let db = Database::generate(0.01, 7);
    let queries = parse_program(ADHOC_SUPPLIER).unwrap();
    assert_eq!(queries.len(), 1);
    let q = &queries[0];

    let pim = PimSession::new(&cfg, &db)
        .unwrap()
        .run_query(q, EngineKind::Native)
        .unwrap();
    let base = baseline::run_query(&cfg, &db, q);
    assert_eq!(pim.output, base.output, "engines disagree on {}", q.name);

    // scalar oracle
    let rel = db.rel(RelId::Supplier);
    let rq = &q.rels[0];
    let mut count = 0u64;
    let mut sum = 0u128;
    for i in 0..rel.records {
        let get = |n: &str| rel.col(n)[i];
        if rq.filter.eval(&get) {
            count += 1;
            sum += get("s_acctbal") as u128;
        }
    }
    assert!(count > 0, "selectivity check: the ad-hoc filter matches rows");
    assert!(count < rel.records as u64, "filter must not select everything");
    assert_eq!(pim.output.selected[0].1, count);
    let g = &pim.output.groups[0];
    assert_eq!(g.values[0], ("suppliers", count as f64));
    assert_eq!(g.values[1], ("sum_bal", sum as f64));
    assert_eq!(g.values[2], ("avg_bal", sum as f64 / count as f64));
}

#[test]
fn adhoc_grouped_customer_query_matches_baseline() {
    let cfg = SystemConfig::default();
    let db = Database::generate(0.01, 7);
    let queries = parse_program(ADHOC_CUSTOMER).unwrap();
    let q = &queries[0];

    let pim = PimSession::new(&cfg, &db)
        .unwrap()
        .run_query(q, EngineKind::Native)
        .unwrap();
    let base = baseline::run_query(&cfg, &db, q);
    assert_eq!(pim.output, base.output, "engines disagree on {}", q.name);
    // 5 market segments exist; at this scale all should be populated
    assert!(!pim.output.groups.is_empty());
    for g in &pim.output.groups {
        assert_eq!(g.key[0].0, "c_mktsegment");
        assert!(g.count > 0);
    }
}

#[test]
fn adhoc_batch_shares_the_session() {
    // two ad-hoc queries on disjoint relations run as one wave through
    // PimSession::run_queries — same path as `run --sql` with two blocks
    let cfg = SystemConfig { parallelism: 2, ..SystemConfig::default() };
    let db = Database::generate(0.01, 7);
    let src = format!("query a {ADHOC_SUPPLIER}; query b {ADHOC_CUSTOMER}");
    let queries = parse_program(&src).unwrap();
    assert_eq!(queries.len(), 2);
    assert_eq!(queries[0].name, "a");
    let mut session = PimSession::new(&cfg, &db).unwrap();
    let reports = session.run_queries(&queries, EngineKind::Native).unwrap();
    assert_eq!(reports.len(), 2);
    for (q, r) in queries.iter().zip(&reports) {
        let base = baseline::run_query(&cfg, &db, q);
        assert_eq!(r.output, base.output, "{}", q.name);
    }
}
