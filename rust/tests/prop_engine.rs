//! Property tests for the bit-plane functional core: `XbarState` ops
//! (`exec_instr` over And/Or/Not/Reduce/ColumnTransform) are checked
//! against a row-at-a-time scalar oracle on random plane contents, widths,
//! and column ranges, and the sharded parallel executor is checked
//! bit-identical to the serial interpreter at every shard/thread count.

use pimdb::exec::engine::{exec_instr, exec_steps_native, Scratch, XbarState};
use pimdb::exec::pimdb::EngineKind;
use pimdb::exec::plan::{exec_steps_sharded, ExecPlan};
use pimdb::pim::endurance::OpCategory;
use pimdb::pim::isa::{ColRange, Opcode, PimInstruction};
use pimdb::query::compiler::Step;
use pimdb::util::bits::{WORDS, XBAR_ROWS};
use pimdb::util::proptest::{check, Gen};
use pimdb::util::rng::Rng;

/// Load per-row values (LSB-first) into the bit-planes starting at `start`.
fn load(st: &mut XbarState, start: usize, bits: usize, vals: &[u64]) {
    for (row, &v) in vals.iter().enumerate() {
        for b in 0..bits {
            if (v >> b) & 1 == 1 {
                st.planes[start + b][row / 64] |= 1 << (row % 64);
            }
        }
    }
}

/// One-shot `exec_instr` with a throwaway scratch arena.
fn run(st: &mut XbarState, instr: &PimInstruction, out: &mut Vec<u128>) {
    exec_instr(st, instr, out, &mut Scratch::new());
}

fn read(st: &XbarState, start: usize, bits: usize, row: usize) -> u64 {
    st.value_at(row, ColRange::new(start, bits))
}

fn rand_vals(g: &mut Gen, bits: usize) -> Vec<u64> {
    let max = (1u64 << bits) - 1;
    g.vec_u64(XBAR_ROWS, 0, max)
}

#[test]
fn and_or_not_match_scalar_oracle() {
    check("prop-logic-oracle", 30, |g| {
        let bits = g.usize(1, 16);
        let a_start = g.usize(0, 8);
        let b_start = a_start + bits + g.usize(0, 8);
        let d_start = b_start + bits + g.usize(0, 8);
        let a_vals = rand_vals(g, bits);
        let b_vals = rand_vals(g, bits);
        let mut st = XbarState::new(d_start + 3 * bits + 4);
        load(&mut st, a_start, bits, &a_vals);
        load(&mut st, b_start, bits, &b_vals);
        let a = ColRange::new(a_start, bits);
        let b = ColRange::new(b_start, bits);
        let mut out = Vec::new();
        run(
            &mut st,
            &PimInstruction::binary(Opcode::And, a, b, ColRange::new(d_start, bits)),
            &mut out,
        );
        run(
            &mut st,
            &PimInstruction::binary(Opcode::Or, a, b, ColRange::new(d_start + bits, bits)),
            &mut out,
        );
        run(
            &mut st,
            &PimInstruction::unary(Opcode::Not, a, ColRange::new(d_start + 2 * bits, bits)),
            &mut out,
        );
        let mask = (1u64 << bits) - 1;
        for row in 0..XBAR_ROWS {
            let (va, vb) = (a_vals[row], b_vals[row]);
            assert_eq!(read(&st, d_start, bits, row), va & vb, "AND row {row}");
            assert_eq!(
                read(&st, d_start + bits, bits, row),
                va | vb,
                "OR row {row}"
            );
            assert_eq!(
                read(&st, d_start + 2 * bits, bits, row),
                !va & mask,
                "NOT row {row}"
            );
        }
        assert!(out.is_empty(), "logic ops must not emit reduce values");
    });
}

#[test]
fn broadcast_and_masks_per_row() {
    check("prop-broadcast-and", 30, |g| {
        let bits = g.usize(2, 20);
        let a_vals = rand_vals(g, bits);
        let mut st = XbarState::new(128);
        load(&mut st, 0, bits, &a_vals);
        // random 1-bit mask column at 90
        let mask_vals: Vec<u64> = (0..XBAR_ROWS).map(|_| g.u64(0, 1)).collect();
        load(&mut st, 90, 1, &mask_vals);
        let mut out = Vec::new();
        run(
            &mut st,
            &PimInstruction::binary(
                Opcode::And,
                ColRange::new(0, bits),
                ColRange::new(90, 1),
                ColRange::new(40, bits),
            ),
            &mut out,
        );
        for row in 0..XBAR_ROWS {
            let want = if mask_vals[row] == 1 { a_vals[row] } else { 0 };
            assert_eq!(read(&st, 40, bits, row), want, "row {row}");
        }
    });
}

#[test]
fn reduce_sum_min_max_match_scalar_oracle() {
    check("prop-reduce-oracle", 25, |g| {
        let bits = g.usize(1, 24);
        let start = g.usize(0, 12);
        let vals = rand_vals(g, bits);
        let mut st = XbarState::new(64);
        load(&mut st, start, bits, &vals);
        let a = ColRange::new(start, bits);
        let mut out = Vec::new();
        for op in [Opcode::ReduceSum, Opcode::ReduceMin, Opcode::ReduceMax] {
            run(&mut st, &PimInstruction::unary(op, a, a), &mut out);
        }
        let want_sum: u128 = vals.iter().map(|&v| v as u128).sum();
        let want_min = *vals.iter().min().unwrap() as u128;
        let want_max = *vals.iter().max().unwrap() as u128;
        assert_eq!(out, vec![want_sum, want_min, want_max], "bits {bits}");
        // reduces must not disturb the operand planes
        for (row, &v) in vals.iter().enumerate() {
            assert_eq!(read(&st, start, bits, row), v);
        }
    });
}

#[test]
fn column_transform_is_a_functional_noop() {
    check("prop-coltrans-noop", 10, |g| {
        let bits = g.usize(1, 8);
        let vals = rand_vals(g, bits);
        let mut st = XbarState::new(64);
        load(&mut st, 0, bits, &vals);
        let before = st.planes.clone();
        let mut out = Vec::new();
        run(
            &mut st,
            &PimInstruction::unary(
                Opcode::ColumnTransform,
                ColRange::new(0, 1),
                ColRange::new(0, 1),
            ),
            &mut out,
        );
        assert_eq!(st.planes, before, "data movement must preserve planes");
        assert!(out.is_empty());
    });
}

// --- sharded executor vs the serial interpreter ------------------------------

fn random_states(seed: u64, n: usize) -> Vec<XbarState> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut st = XbarState::new(192);
            for c in 0..40 {
                for w in 0..WORDS {
                    st.planes[c][w] = rng.next_u64();
                }
            }
            st
        })
        .collect()
}

fn mixed_program() -> Vec<Step> {
    let step = |instr| Step {
        instr,
        category: OpCategory::Filter,
    };
    vec![
        step(PimInstruction::with_imm(
            Opcode::LtImm,
            ColRange::new(0, 20),
            ColRange::new(100, 1),
            0xBEEF,
        )),
        step(PimInstruction::with_imm(
            Opcode::GtImm,
            ColRange::new(20, 20),
            ColRange::new(101, 1),
            0x1111,
        )),
        step(PimInstruction::binary(
            Opcode::Or,
            ColRange::new(100, 1),
            ColRange::new(101, 1),
            ColRange::new(102, 1),
        )),
        step(PimInstruction::binary(
            Opcode::And,
            ColRange::new(0, 20),
            ColRange::new(102, 1),
            ColRange::new(110, 20),
        )),
        step(PimInstruction::binary(
            Opcode::Mul,
            ColRange::new(110, 16),
            ColRange::new(20, 16),
            ColRange::new(140, 32),
        )),
        step(PimInstruction::unary(
            Opcode::ReduceSum,
            ColRange::new(140, 32),
            ColRange::new(140, 32),
        )),
        step(PimInstruction::unary(
            Opcode::ReduceMax,
            ColRange::new(140, 32),
            ColRange::new(140, 32),
        )),
    ]
}

#[test]
fn sharded_exec_bit_identical_at_1_2_8_and_random_shards() {
    let steps = mixed_program();
    check("prop-sharded-identical", 10, |g| {
        let n = g.usize(1, 13);
        let seed = g.u64(0, 1 << 40);
        let mut serial_states = random_states(seed, n);
        let want = exec_steps_native(&mut serial_states, &steps, 102);
        for shards in [1usize, 2, 8, g.usize(1, 24)] {
            let plan = ExecPlan {
                parallelism: g.usize(1, 8),
                shards_per_program: shards,
            };
            let mut states = random_states(seed, n);
            let got = exec_steps_sharded(&mut states, &steps, 102, EngineKind::Native, &plan)
                .unwrap();
            assert_eq!(want.reduces, got.reduces, "{n} xbars, {shards} shards");
            assert_eq!(
                want.mask_counts, got.mask_counts,
                "{n} xbars, {shards} shards"
            );
            for (a, b) in serial_states.iter().zip(&states) {
                assert_eq!(a.planes, b.planes, "{n} xbars, {shards} shards");
            }
        }
    });
}
