//! Pruning battery: statistics-driven shard pruning, the runtime
//! all-zero short-circuit, and cost-ordered predicates must be pure
//! execution shortcuts — bit-identical outputs to the scan-everything
//! baseline and to the unreordered `-O0` path, at shard-pool widths 1,
//! 2 and 8, under DML interleavings, and across the stale-stats window
//! that follows a group commit (a plan whose predicate order was chosen
//! against older statistics keeps executing; only its *order* may be
//! stale — skip bitmaps are always derived from the pinned snapshot's
//! stats, never cached across epochs).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};

use pimdb::api::{Pimdb, QuerySource};
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::db::schema::{self, RelId};
use pimdb::exec::baseline;
use pimdb::exec::metrics::QueryOutput;
use pimdb::query::ast::*;
use pimdb::query::lang::{parse_dml, parse_program};
use pimdb::query::opt::OptLevel;
use pimdb::util::proptest::{check, Gen};

const SEED: u64 = 1061;

fn db() -> Database {
    Database::generate(0.001, SEED)
}

fn cfg_with(parallelism: usize) -> SystemConfig {
    SystemConfig {
        parallelism,
        ..SystemConfig::default()
    }
}

fn rand_attr(g: &mut Gen, rel: RelId) -> (&'static str, usize) {
    let attrs = schema::attrs(rel);
    let a = attrs[g.usize(0, attrs.len() - 1)];
    (a.name, a.bits)
}

fn rand_value(g: &mut Gen, bits: usize) -> u64 {
    let max = if bits >= 64 { u64::MAX } else { (1 << bits) - 1 };
    g.u64(0, max.min(1 << bits.min(40)))
}

/// Random predicates biased toward zone-prunable shapes: plenty of
/// single-attribute range compares (what the decision table reasons
/// about exactly), mixed with IN-sets, BETWEENs and And/Or/Not nests
/// (where it must stay conservative).
fn rand_pred(g: &mut Gen, rel: RelId, depth: usize) -> Pred {
    if depth == 0 || g.u64(0, 2) == 0 {
        let (attr, bits) = rand_attr(g, rel);
        match g.u64(0, 3) {
            0 | 1 => {
                let ops = [CmpOp::Eq, CmpOp::Ne, CmpOp::Lt, CmpOp::Le, CmpOp::Gt, CmpOp::Ge];
                Pred::CmpImm {
                    attr,
                    op: *g.pick(&ops),
                    value: rand_value(g, bits),
                }
            }
            2 => Pred::InSet {
                attr,
                values: (0..g.usize(1, 4)).map(|_| rand_value(g, bits)).collect(),
            },
            _ => {
                let a = rand_value(g, bits);
                let b = rand_value(g, bits);
                Pred::Between {
                    attr,
                    lo: a.min(b),
                    hi: a.max(b),
                }
            }
        }
    } else {
        let n = g.usize(1, 3);
        let subs: Vec<Pred> = (0..n).map(|_| rand_pred(g, rel, depth - 1)).collect();
        match g.u64(0, 2) {
            0 => Pred::And(subs),
            1 => Pred::Or(subs),
            _ => Pred::Not(Box::new(rand_pred(g, rel, depth - 1))),
        }
    }
}

fn rand_query(g: &mut Gen, rel: RelId) -> Query {
    let (attr, _) = rand_attr(g, rel);
    let aggregates = if g.u64(0, 1) == 0 {
        vec![
            Aggregate {
                kind: AggKind::Sum,
                expr: ValExpr::Attr(attr),
                label: "s",
            },
            Aggregate {
                kind: AggKind::Count,
                expr: ValExpr::One,
                label: "n",
            },
        ]
    } else {
        vec![]
    };
    let kind = if aggregates.is_empty() {
        QueryKind::FilterOnly
    } else {
        QueryKind::Full
    };
    Query {
        name: "prune_fuzz",
        kind,
        rels: vec![RelQuery {
            rel,
            filter: rand_pred(g, rel, 2),
            group_by: vec![],
            aggregates,
        }],
    }
}

/// Random queries through the pruning path (api handle: skip bitmaps,
/// short-circuit, cost-ordered predicates) against the scan-everything
/// baseline, at every shard-pool width — outputs bit-identical.
#[test]
fn random_pruned_queries_match_scan_everything_oracle() {
    let cfg = cfg_with(1);
    let data = db();
    // AssertUnwindSafe: `check` catches panics to report the failing
    // case; the handles are dropped right after, never reused across a
    // caught panic
    let handles = std::panic::AssertUnwindSafe(
        [1usize, 2, 8]
            .iter()
            .map(|&w| Pimdb::open(cfg_with(w), db()).unwrap())
            .collect::<Vec<Pimdb>>(),
    );
    check("pruned-vs-baseline", 40, |g| {
        let handles = &handles.0;
        let rel = *g.pick(&[
            RelId::Lineitem,
            RelId::Orders,
            RelId::Supplier,
            RelId::Part,
            RelId::Customer,
        ]);
        let q = rand_query(g, rel);
        let want = baseline::run_query(&cfg, &data, &q).output;
        for handle in handles {
            let got = handle
                .prepare(QuerySource::Ast(&q))
                .unwrap()
                .execute()
                .unwrap()
                .raw_report()
                .output
                .clone();
            assert_eq!(got, want, "pruned drift on {:?}", q.rels[0].filter);
        }
    });
}

/// The reordering pass is proven inert on outputs by an O0-vs-O2
/// differential: the same random queries through handles at both opt
/// levels (O0 never reorders; O2 reorders whenever stats make a
/// segment order profitable) — identical outputs everywhere.
#[test]
fn o0_vs_o2_differential_with_pruning() {
    let pair = std::panic::AssertUnwindSafe((
        Pimdb::open(cfg_with(2), db()).unwrap(),
        Pimdb::open(
            SystemConfig {
                opt_level: OptLevel::O0,
                parallelism: 2,
                ..SystemConfig::default()
            },
            db(),
        )
        .unwrap(),
    ));
    check("prune-o0-vs-o2", 25, |g| {
        let (o2, o0) = (&pair.0 .0, &pair.0 .1);
        let rel = *g.pick(&[RelId::Lineitem, RelId::Orders, RelId::Supplier]);
        let q = rand_query(g, rel);
        let a = o2
            .prepare(QuerySource::Ast(&q))
            .unwrap()
            .execute()
            .unwrap()
            .raw_report()
            .output
            .clone();
        let b = o0
            .prepare(QuerySource::Ast(&q))
            .unwrap()
            .execute()
            .unwrap()
            .raw_report()
            .output
            .clone();
        assert_eq!(a, b, "-O0/-O2 drift on {:?}", q.rels[0].filter);
    });
}

/// Random DML interleaved with random queries at each pool width: after
/// every statement the api handle (incrementally maintained zone maps)
/// must keep matching a baseline twin that re-scans everything.
#[test]
fn pruned_execution_matches_oracle_across_dml_interleavings() {
    for workers in [1usize, 2, 8] {
        let cfg = cfg_with(workers);
        check(&format!("prune-dml-w{workers}"), 6, |g| {
            let handle = Pimdb::open(cfg.clone(), db()).unwrap();
            let mut oracle = db();
            let mut next_key = 9000 + g.u64(0, 100);
            for _ in 0..6 {
                let stmt = match g.u64(0, 4) {
                    0 => format!(
                        "delete from supplier where s_suppkey == {}",
                        g.u64(1, 10)
                    ),
                    1 => format!(
                        "delete from lineitem where l_orderkey <= {}",
                        g.u64(1, 300)
                    ),
                    2 => format!(
                        "update supplier set s_nationkey = {} where s_suppkey >= {}",
                        g.u64(0, 24),
                        g.u64(1, 10)
                    ),
                    3 => format!(
                        "update lineitem set l_discount = {} where l_orderkey <= {}",
                        g.u64(0, 10),
                        g.u64(1, 200)
                    ),
                    _ => {
                        next_key += 1;
                        format!(
                            "insert into supplier (s_suppkey, s_acctbal) values ({next_key}, 123.45)"
                        )
                    }
                };
                let got = handle.execute_dml(stmt.as_str()).unwrap();
                let dml = parse_dml(&stmt).unwrap();
                let want = baseline::apply_dml(&cfg, &mut oracle, &dml);
                assert_eq!(got.rows_affected, want.rows_affected, "{stmt}");
                for rel in [RelId::Lineitem, RelId::Supplier] {
                    let q = rand_query(g, rel);
                    let got = handle
                        .prepare(QuerySource::Ast(&q))
                        .unwrap()
                        .execute()
                        .unwrap()
                        .raw_report()
                        .output
                        .clone();
                    let want = baseline::run_query(&cfg, &oracle, &q).output;
                    assert_eq!(
                        got, want,
                        "post-DML drift after `{stmt}` on {:?}",
                        q.rels[0].filter
                    );
                }
            }
        });
    }
}

/// Selective key-range filter over LINEITEM (loaded in ascending
/// l_orderkey order, so trailing crossbars are provably disjoint):
/// shards are actually skipped at every pool width, and a doubly
/// contradictory filter short-circuits at runtime — all while matching
/// the baseline.
#[test]
fn pruning_counters_fire_on_selective_filters_at_every_width() {
    let cfg = cfg_with(1);
    let data = db();
    let selective = "from lineitem | filter l_orderkey <= 64 \
                     | aggregate count() as n, sum(l_extendedprice) as s";
    let contradictory = "from lineitem | filter \
        l_shipdate >= date(1994-06-01) and l_shipdate < date(1994-06-01) \
        and l_quantity < 10 and l_quantity >= 10 \
        | aggregate count() as n";
    for workers in [1usize, 2, 8] {
        let handle = Pimdb::open(cfg_with(workers), db()).unwrap();
        for (text, wants_skip, wants_sc) in
            [(selective, true, false), (contradictory, false, true)]
        {
            let q = &parse_program(text).unwrap()[0];
            let r = handle.prepare(text).unwrap().execute().unwrap();
            assert_eq!(
                r.raw_report().output,
                baseline::run_query(&cfg, &data, q).output,
                "{text} at {workers} workers"
            );
            let m = &r.raw_report().metrics;
            if wants_skip {
                assert!(
                    m.shards_skipped > 0,
                    "no shards skipped for `{text}` at {workers} workers"
                );
            }
            if wants_sc {
                assert!(
                    m.steps_short_circuited > 0,
                    "no short-circuit for `{text}` at {workers} workers"
                );
            }
        }
    }
}

/// The stale-stats window: a statement prepared at epoch 0 (its
/// predicate order frozen by the plan cache) keeps executing while a
/// writer group-commits deletes that move the zone boundaries under it.
/// Every concurrent result must equal some committed oracle state,
/// observed monotonically; after the dust settles the stale-ordered
/// plan still prunes correctly against the *new* stats.
fn stale_stats_scenario(workers: usize, n_readers: usize) {
    let cfg = cfg_with(workers);
    let probe = "from lineitem | filter l_orderkey <= 256 \
                 | aggregate count() as n, sum(l_extendedprice) as s";
    let q = &parse_program(probe).unwrap()[0];
    let cuts: Vec<u64> = vec![64, 128, 192, 256];

    // oracle chain: baseline twin after each committed delete
    let mut oracle = db();
    let mut chain: Vec<QueryOutput> = vec![baseline::run_query(&cfg, &oracle, q).output];
    for &k in &cuts {
        let dml = parse_dml(&format!("delete from lineitem where l_orderkey <= {k}")).unwrap();
        baseline::apply_dml(&cfg, &mut oracle, &dml);
        chain.push(baseline::run_query(&cfg, &oracle, q).output);
    }

    let handle = Arc::new(Pimdb::open(cfg, db()).unwrap());
    // prepared before any DML: its cost-based order came from epoch-0
    // zone maps and is never re-derived for the plan's lifetime
    let prepared = handle.prepare(probe).unwrap();
    let done = AtomicBool::new(false);
    let start = Barrier::new(n_readers + 1);

    std::thread::scope(|s| {
        let mut readers = Vec::new();
        for _ in 0..n_readers {
            readers.push(s.spawn(|| {
                let mut last = 0usize;
                start.wait();
                loop {
                    let stop = done.load(Ordering::Acquire);
                    let out = prepared.execute().unwrap().raw_report().output.clone();
                    let idx = chain
                        .iter()
                        .position(|c| *c == out)
                        .expect("stale-window result outside the commit chain");
                    assert!(idx >= last, "chain ran backwards: {last} -> {idx}");
                    last = idx;
                    if stop {
                        break;
                    }
                }
            }));
        }
        start.wait();
        for &k in &cuts {
            handle
                .execute_dml(format!("delete from lineitem where l_orderkey <= {k}").as_str())
                .unwrap();
        }
        done.store(true, Ordering::Release);
        for r in readers {
            r.join().unwrap();
        }
    });

    // post-commit: every crossbar's l_orderkey zone now starts above the
    // probe's cut, so the stale-ordered plan skips the whole relation —
    // and still reports exactly the final oracle state
    let r = prepared.execute().unwrap();
    assert_eq!(r.raw_report().output, chain[cuts.len()]);
    assert!(
        r.raw_report().metrics.shards_skipped > 0,
        "rebuilt zone maps should prune the emptied key range"
    );
}

#[test]
fn stale_stats_window_serial_pool() {
    stale_stats_scenario(1, 2);
}

#[test]
fn stale_stats_window_two_workers() {
    stale_stats_scenario(2, 2);
}

#[test]
fn stale_stats_window_eight_workers() {
    stale_stats_scenario(8, 4);
}
