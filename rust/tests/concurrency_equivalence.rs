//! Concurrency battery: snapshot reads under concurrent DML.
//!
//! The snapshot/group-commit facade promises exactly three things, and
//! each test here attacks one of them:
//!
//! 1. **Snapshot isolation** — every query result equals one committed
//!    state of the relation: the pre-batch oracle or a post-batch
//!    oracle, never a torn mixture, and a single reader observes the
//!    commit chain monotonically (epochs never run backwards).
//! 2. **Non-blocking reads** — readers keep completing queries while
//!    DML statements are executing wall-clock-concurrently (interval
//!    overlap between reader executions and writer statements).
//! 3. **Race-free bookkeeping** — shared-scan counters account for
//!    every scan-eligible execution exactly once, per-row wear is
//!    monotone under interleaving, and the final state is bit-identical
//!    to a serial application of the same statements.
//!
//! The whole battery runs at shard-pool parallelism 1 (inline serial
//! executor), 2 and 8 — the facade's concurrency rules must not depend
//! on how the crossbar work itself is fanned out.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Instant;

use pimdb::api::Pimdb;
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::db::schema::RelId;
use pimdb::exec::metrics::QueryOutput;

/// Seed 7 generates 10 live supplier records with s_suppkey 1..=10
/// (SF 0.001), small enough that oracle chains stay cheap and every
/// single-key delete is a visible fraction of the relation.
fn db() -> Database {
    Database::generate(0.001, 7)
}

fn handle_with(parallelism: usize) -> Pimdb {
    let cfg = SystemConfig {
        parallelism,
        ..SystemConfig::default()
    };
    Pimdb::open(cfg, db()).unwrap()
}

/// The probe query: scan-eligible (filter prefix + aggregate suffix)
/// and state-distinguishing — count and sum together change on every
/// single-row delete of the chains below.
const PROBE: &str =
    "from supplier | filter s_suppkey >= 1 | aggregate sum(s_acctbal) as s";

fn probe_output(h: &Pimdb) -> QueryOutput {
    h.prepare(PROBE)
        .unwrap()
        .execute()
        .unwrap()
        .raw_report()
        .output
        .clone()
}

fn delete_stmt(key: u64) -> String {
    format!("delete from supplier where s_suppkey == {key}")
}

/// Flip the stop flag even when the owning thread panics mid-scenario,
/// so reader loops always terminate and the scope can join (a reader
/// spinning on a flag a dead writer never set would hang the suite).
struct StopOnDrop<'a>(&'a AtomicBool);

impl Drop for StopOnDrop<'_> {
    fn drop(&mut self) {
        self.0.store(true, Ordering::Release);
    }
}

/// Single writer applying a known chain of single-row deletes while N
/// readers hammer the probe. Every reader result must equal exactly one
/// oracle chain state, observed in monotone chain order; reads and
/// writer statements must overlap in wall-clock time; scan counters
/// must account for every probe execution exactly once.
fn chain_scenario(parallelism: usize, n_readers: usize) {
    let keys: Vec<u64> = (1..=8).collect();

    // Oracle chain: outputs[j] is the committed state after j deletes.
    let oracle = handle_with(parallelism);
    let mut chain = vec![probe_output(&oracle)];
    for &k in &keys {
        let r = oracle.execute_dml(delete_stmt(k).as_str()).unwrap();
        assert_eq!(r.rows_affected, 1, "oracle delete of key {k}");
        chain.push(probe_output(&oracle));
    }
    // every chain state is distinct, so "which state did I read" is
    // well-defined for the monotonicity check below
    for i in 0..chain.len() {
        for j in (i + 1)..chain.len() {
            assert_ne!(chain[i], chain[j], "chain states {i} and {j} collide");
        }
    }

    let handle = Arc::new(handle_with(parallelism));
    let initial = handle.live_records(RelId::Supplier);
    // warm the plan so reader iterations measure execution, not compile
    let prepared = handle.prepare(PROBE).unwrap();
    drop(prepared);

    let done = AtomicBool::new(false);
    let probes_run = AtomicU64::new(0);
    let start = Barrier::new(n_readers + 1);
    let epoch0 = Instant::now();

    // (start, end) offsets in nanos since epoch0
    let mut reader_spans: Vec<Vec<(u128, u128)>> = Vec::new();
    let mut writer_spans: Vec<(u128, u128)> = Vec::new();

    std::thread::scope(|s| {
        let mut readers = Vec::new();
        for _ in 0..n_readers {
            readers.push(s.spawn(|| {
                let p = handle.prepare(PROBE).unwrap();
                let mut spans = Vec::new();
                let mut last_idx = 0usize;
                let mut last_wear = 0u64;
                start.wait();
                loop {
                    let stop = done.load(Ordering::Acquire);
                    let t0 = epoch0.elapsed().as_nanos();
                    let out = p.execute().unwrap().raw_report().output.clone();
                    let t1 = epoch0.elapsed().as_nanos();
                    probes_run.fetch_add(1, Ordering::Relaxed);
                    spans.push((t0, t1));
                    // snapshot isolation: the result IS a chain state
                    let idx = chain
                        .iter()
                        .position(|c| *c == out)
                        .expect("reader observed a state outside the commit chain");
                    // epochs never run backwards for one reader
                    assert!(
                        idx >= last_idx,
                        "chain ran backwards: {last_idx} -> {idx}"
                    );
                    last_idx = idx;
                    // wear is monotone under concurrent folding
                    let wear: u64 = handle.wear_counters(RelId::Supplier).iter().sum();
                    assert!(wear >= last_wear, "wear decreased: {last_wear} -> {wear}");
                    last_wear = wear;
                    if stop {
                        break;
                    }
                }
                spans
            }));
        }

        // writer: the same chain, one statement at a time
        let _stop = StopOnDrop(&done);
        start.wait();
        for &k in &keys {
            let t0 = epoch0.elapsed().as_nanos();
            let r = handle.execute_dml(delete_stmt(k).as_str()).unwrap();
            let t1 = epoch0.elapsed().as_nanos();
            writer_spans.push((t0, t1));
            assert_eq!(r.rows_affected, 1, "stress delete of key {k}");
        }
        done.store(true, Ordering::Release);

        for r in readers {
            reader_spans.push(r.join().unwrap());
        }
    });

    // final state: end of the chain, same live count, same output
    assert_eq!(
        handle.live_records(RelId::Supplier),
        initial - keys.len()
    );
    let final_probes = 1u64;
    assert_eq!(probe_output(&handle), chain[keys.len()]);

    // non-blocking reads: some reader execution overlapped some writer
    // statement in wall-clock time (readers run back-to-back across the
    // writer's whole window, so overlap is structural, not lucky timing)
    let overlapped = reader_spans.iter().flatten().any(|&(rs, re)| {
        writer_spans
            .iter()
            .any(|&(ws, we)| rs < we && ws < re)
    });
    assert!(
        overlapped,
        "no reader execution overlapped any writer statement"
    );

    // race-free counters: every probe execution (readers + the final
    // check above) hit or missed the scan cache exactly once; DML
    // statements never touch these counters
    let sc = handle.shared_scan_counters();
    assert_eq!(
        sc.hits + sc.misses,
        probes_run.load(Ordering::Relaxed) + final_probes,
        "scan counters lost or double-counted an execution"
    );
}

#[test]
fn snapshot_reads_match_the_commit_chain_serial_pool() {
    chain_scenario(1, 2);
}

#[test]
fn snapshot_reads_match_the_commit_chain_two_workers() {
    chain_scenario(2, 2);
}

#[test]
fn snapshot_reads_match_the_commit_chain_eight_workers() {
    chain_scenario(8, 4);
}

/// Two writers with disjoint key sets racing on one relation, plus
/// readers. Intermediate counts stay inside [final, initial] and are
/// monotone non-increasing per reader (deletes only remove rows); the
/// final contents are bit-identical to a serial application.
fn multi_writer_scenario(parallelism: usize) {
    let handle = Arc::new(handle_with(parallelism));
    let initial = handle.live_records(RelId::Supplier) as i64;
    let sets: [&[u64]; 2] = [&[1, 2, 3, 4], &[5, 6, 7, 8]];
    let total: usize = sets.iter().map(|s| s.len()).sum();

    let count_probe = "from supplier | filter s_suppkey >= 1 | aggregate count() as n";
    let done = AtomicBool::new(false);
    // participants: every writer, every reader, and the watcher below
    let start = Barrier::new(sets.len() + 2 + 1);

    std::thread::scope(|s| {
        for set in sets {
            s.spawn(|| {
                start.wait();
                for &k in set {
                    let r = handle.execute_dml(delete_stmt(k).as_str()).unwrap();
                    assert_eq!(r.rows_affected, 1, "delete of key {k}");
                }
            });
        }
        for _ in 0..2 {
            s.spawn(|| {
                let p = handle.prepare(count_probe).unwrap();
                let mut last = i64::MAX;
                let mut last_wear = 0u64;
                start.wait();
                loop {
                    let stop = done.load(Ordering::Acquire);
                    let n = p
                        .execute()
                        .unwrap()
                        .rows()
                        .row(0)
                        .unwrap()
                        .get("n")
                        .unwrap()
                        .as_i64()
                        .unwrap();
                    assert!(
                        n >= initial - total as i64 && n <= initial,
                        "count {n} outside [{}, {initial}]",
                        initial - total as i64
                    );
                    assert!(n <= last, "count increased under deletes: {last} -> {n}");
                    last = n;
                    let wear: u64 = handle.wear_counters(RelId::Supplier).iter().sum();
                    assert!(wear >= last_wear, "wear decreased: {last_wear} -> {wear}");
                    last_wear = wear;
                    if stop {
                        break;
                    }
                }
            });
        }
        // watcher: readers stop once every delete has committed (or on
        // a generous timeout so a failed writer can't hang the scope —
        // the final asserts below then report the real divergence)
        let _stop = StopOnDrop(&done);
        start.wait();
        let deadline = Instant::now() + std::time::Duration::from_secs(120);
        loop {
            if handle.live_records(RelId::Supplier) as i64 == initial - total as i64
                || Instant::now() > deadline
            {
                break;
            }
            std::thread::yield_now();
        }
        done.store(true, Ordering::Release);
    });

    // serial twin: same statements, one at a time, fresh handle
    let serial = handle_with(parallelism);
    for set in sets {
        for &k in set {
            serial.execute_dml(delete_stmt(k).as_str()).unwrap();
        }
    }
    assert_eq!(
        handle.live_records(RelId::Supplier),
        serial.live_records(RelId::Supplier)
    );
    assert_eq!(probe_output(&handle), probe_output(&serial));
    // both handles committed the same total wear for the same deletes
    // (per-row placement can differ with batching, totals cannot)
    let wa: u64 = handle.wear_counters(RelId::Supplier).iter().sum();
    let wb: u64 = serial.wear_counters(RelId::Supplier).iter().sum();
    assert_eq!(wa, wb, "total committed wear diverged from the serial twin");
}

#[test]
fn disjoint_writers_group_commit_serializably() {
    multi_writer_scenario(2);
}

#[test]
fn disjoint_writers_group_commit_serializably_eight_workers() {
    multi_writer_scenario(8);
}

/// A reader that pinned its snapshot *before* a delete commits keeps
/// seeing the deleted row through its whole execution, while a reader
/// that pins after sees it gone — the pre/post rule at the finest
/// possible grain, repeated enough times to give interleaving a chance.
#[test]
fn readers_pin_pre_or_post_batch_states_only() {
    let handle = Arc::new(handle_with(2));
    let keys: Vec<u64> = (1..=8).collect();
    let chain_handle = handle_with(2);
    let mut chain = vec![probe_output(&chain_handle)];
    for &k in &keys {
        chain_handle.execute_dml(delete_stmt(k).as_str()).unwrap();
        chain.push(probe_output(&chain_handle));
    }

    let start = Barrier::new(2);
    std::thread::scope(|s| {
        let reader = s.spawn(|| {
            let p = handle.prepare(PROBE).unwrap();
            start.wait();
            let mut seen = Vec::new();
            for _ in 0..64 {
                let out = p.execute().unwrap().raw_report().output.clone();
                let idx = chain
                    .iter()
                    .position(|c| *c == out)
                    .expect("result outside the commit chain");
                seen.push(idx);
            }
            seen
        });
        start.wait();
        for &k in &keys {
            handle.execute_dml(delete_stmt(k).as_str()).unwrap();
        }
        let seen = reader.join().unwrap();
        // monotone, starts at or after 0, ends at or before the full chain
        assert!(seen.windows(2).all(|w| w[0] <= w[1]), "chain ran backwards");
        assert!(*seen.last().unwrap() <= keys.len());
    });
    assert_eq!(probe_output(&handle), chain[keys.len()]);
}

/// The same prepared statement object is safe to share: many threads
/// executing one `Prepared` against one relation under DML, all results
/// on-chain, counters exact.
#[test]
fn one_prepared_statement_shared_across_threads_under_dml() {
    let handle = Arc::new(handle_with(2));
    let chain_handle = handle_with(2);
    let keys: Vec<u64> = (1..=6).collect();
    let mut chain = vec![probe_output(&chain_handle)];
    for &k in &keys {
        chain_handle.execute_dml(delete_stmt(k).as_str()).unwrap();
        chain.push(probe_output(&chain_handle));
    }

    let prepared = handle.prepare(PROBE).unwrap();
    let executions = AtomicU64::new(0);
    let start = Barrier::new(5);
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                start.wait();
                for _ in 0..16 {
                    let out = prepared.execute().unwrap().raw_report().output.clone();
                    executions.fetch_add(1, Ordering::Relaxed);
                    assert!(
                        chain.contains(&out),
                        "shared-statement result outside the commit chain"
                    );
                }
            });
        }
        start.wait();
        for &k in &keys {
            handle.execute_dml(delete_stmt(k).as_str()).unwrap();
        }
    });
    let sc = handle.shared_scan_counters();
    assert_eq!(sc.hits + sc.misses, executions.load(Ordering::Relaxed));
}
