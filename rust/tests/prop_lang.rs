//! Property: pretty-printing a random `RelQuery` as PQL text and
//! re-parsing it reproduces the AST node-for-node — the text frontend
//! loses nothing the engine can express (empty IN-sets excepted, which no
//! text can construct). Runs on the deterministic mini-proptest harness
//! from `pimdb::util::proptest`.

use pimdb::db::schema::{self, Encoding, RelId, PIM_RELATIONS};
use pimdb::query::ast::{AggKind, Aggregate, CmpOp, Pred, RelQuery, ValExpr};
use pimdb::query::lang::{parse_program, print};
use pimdb::util::proptest::{check, Gen};

const OPS: [CmpOp; 6] = [
    CmpOp::Eq,
    CmpOp::Ne,
    CmpOp::Lt,
    CmpOp::Le,
    CmpOp::Gt,
    CmpOp::Ge,
];

const KINDS: [AggKind; 5] = [
    AggKind::Sum,
    AggKind::Count,
    AggKind::Min,
    AggKind::Max,
    AggKind::Avg,
];

const LABELS: [&str; 6] = ["v0", "v1", "v2", "v3", "v4", "total"];

fn rand_value(g: &mut Gen, bits: usize) -> u64 {
    let max = if bits >= 64 { u64::MAX } else { (1u64 << bits) - 1 };
    g.u64(0, max)
}

fn rand_attr(g: &mut Gen, rel: RelId) -> &'static schema::Attr {
    let attrs = schema::attrs(rel);
    &attrs[g.usize(0, attrs.len() - 1)]
}

/// Column pairs comparable in the AST (same encoding kind, same width).
fn cmp_col_pairs(rel: RelId) -> Vec<(&'static str, &'static str)> {
    let attrs = schema::attrs(rel);
    let mut pairs = Vec::new();
    for a in attrs {
        for b in attrs {
            if a.name != b.name
                && a.bits == b.bits
                && std::mem::discriminant(&a.enc) == std::mem::discriminant(&b.enc)
            {
                pairs.push((a.name, b.name));
            }
        }
    }
    pairs
}

fn leaf(g: &mut Gen, rel: RelId) -> Pred {
    let a = rand_attr(g, rel);
    match g.usize(0, 9) {
        0 => Pred::True,
        1 | 2 => {
            let x = rand_value(g, a.bits);
            let y = rand_value(g, a.bits);
            Pred::Between { attr: a.name, lo: x.min(y), hi: x.max(y) }
        }
        3 | 4 => {
            let n = g.usize(1, 4);
            Pred::InSet {
                attr: a.name,
                values: (0..n).map(|_| rand_value(g, a.bits)).collect(),
            }
        }
        5 => {
            let pairs = cmp_col_pairs(rel);
            if pairs.is_empty() {
                Pred::CmpImm {
                    attr: a.name,
                    op: *g.pick(&OPS),
                    value: rand_value(g, a.bits),
                }
            } else {
                let &(x, y) = g.pick(&pairs);
                Pred::CmpCols { a: x, op: *g.pick(&OPS), b: y }
            }
        }
        _ => Pred::CmpImm {
            attr: a.name,
            op: *g.pick(&OPS),
            value: rand_value(g, a.bits),
        },
    }
}

fn rand_pred(g: &mut Gen, rel: RelId, depth: usize) -> Pred {
    if depth == 0 || g.usize(0, 2) == 0 {
        return leaf(g, rel);
    }
    match g.usize(0, 2) {
        0 => Pred::And(
            (0..g.usize(2, 3)).map(|_| rand_pred(g, rel, depth - 1)).collect(),
        ),
        1 => Pred::Or(
            (0..g.usize(2, 3)).map(|_| rand_pred(g, rel, depth - 1)).collect(),
        ),
        _ => Pred::Not(Box::new(rand_pred(g, rel, depth - 1))),
    }
}

fn rand_val_expr(g: &mut Gen, rel: RelId) -> ValExpr {
    let a = rand_attr(g, rel).name;
    match g.usize(0, 5) {
        0 => ValExpr::One,
        1 => ValExpr::MulAttrs(a, rand_attr(g, rel).name),
        2 => ValExpr::MulComplement {
            attr: a,
            scale: g.u64(1, 200),
            other: rand_attr(g, rel).name,
        },
        3 => ValExpr::MulSum {
            attr: a,
            scale: g.u64(1, 200),
            other: rand_attr(g, rel).name,
        },
        4 => ValExpr::MulComplementSum {
            attr: a,
            scale1: g.u64(1, 200),
            other1: rand_attr(g, rel).name,
            scale2: g.u64(1, 200),
            other2: rand_attr(g, rel).name,
        },
        _ => ValExpr::Attr(a),
    }
}

fn rand_agg(g: &mut Gen, rel: RelId) -> Aggregate {
    let kind = *g.pick(&KINDS);
    // the printer renders Count as `count()`, whose expr is always One
    let expr = if kind == AggKind::Count {
        ValExpr::One
    } else {
        rand_val_expr(g, rel)
    };
    Aggregate { kind, expr, label: *g.pick(&LABELS) }
}

fn rand_group_by(g: &mut Gen, rel: RelId) -> Vec<&'static str> {
    let cands: Vec<&'static str> = schema::attrs(rel)
        .iter()
        .filter(|a| matches!(a.enc, Encoding::Dict) || a.bits <= 6)
        .map(|a| a.name)
        .collect();
    if cands.is_empty() {
        return Vec::new();
    }
    (0..g.usize(0, 2)).map(|_| *g.pick(&cands)).collect()
}

#[test]
fn printed_rel_queries_reparse_identically() {
    check("pql-roundtrip", 256, |g| {
        let rel = *g.pick(&PIM_RELATIONS);
        let filter = rand_pred(g, rel, 2);
        let aggregates: Vec<Aggregate> =
            (0..g.usize(0, 3)).map(|_| rand_agg(g, rel)).collect();
        let group_by = if aggregates.is_empty() {
            Vec::new()
        } else {
            rand_group_by(g, rel)
        };
        let rq = RelQuery { rel, filter, group_by, aggregates };

        let text = print::rel_query_to_pql(&rq);
        let queries = parse_program(&text)
            .unwrap_or_else(|e| panic!("re-parse failed:\n{}\nfor: {text}", e.render(&text)));
        assert_eq!(queries.len(), 1, "{text}");
        assert_eq!(queries[0].rels.len(), 1, "{text}");
        assert_eq!(queries[0].rels[0], rq, "round-trip drift for: {text}");
    });
}
