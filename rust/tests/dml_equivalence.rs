//! Differential DML suite: random interleavings of INSERT/UPDATE/DELETE
//! and queries execute on three independent implementations —
//!
//!  * the PIM engine (`api::Pimdb`: valid-bit masking in the arrays,
//!    endurance-aware free-row allocation, wear accounting),
//!  * the host column-store baseline (`baseline::apply_dml` +
//!    `baseline::run_query` over the mutated store), and
//!  * a `Vec`-backed scalar oracle held by the test —
//!
//! and every functional output must be bit-identical: rows affected,
//! selected counts, aggregate values, group contents. Per-row wear
//! counters must be monotonically nondecreasing across the interleaving.

use std::collections::BTreeMap;

use pimdb::api::{Pimdb, QuerySource};
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::db::schema::{self, RelId};
use pimdb::exec::baseline;
use pimdb::query::ast::{
    AggKind, Aggregate, CmpOp, Dml, Pred, Query, QueryKind, RelQuery, ValExpr,
};
use pimdb::query::tpch;
use pimdb::util::proptest::check;

/// One oracle row: attribute name → encoded value.
type Row = BTreeMap<&'static str, u64>;

fn oracle_rows(db: &Database, rel: RelId) -> Vec<Row> {
    let r = db.rel(rel);
    (0..r.records)
        .filter(|&i| r.live(i))
        .map(|i| {
            schema::attrs(rel)
                .iter()
                .map(|a| (a.name, r.col(a.name)[i]))
                .collect()
        })
        .collect()
}

fn oracle_apply(rows: &mut Vec<Row>, rel: RelId, dml: &Dml) -> u64 {
    match dml {
        Dml::Insert { values, .. } => {
            let mut row: Row = schema::attrs(rel).iter().map(|a| (a.name, 0)).collect();
            for (n, v) in values {
                row.insert(n, *v);
            }
            rows.push(row);
            1
        }
        Dml::Update { filter, sets, .. } => {
            let mut n = 0;
            for row in rows.iter_mut() {
                if filter.eval(&|a: &str| *row.get(a).unwrap_or(&0)) {
                    for (name, v) in sets {
                        row.insert(name, *v);
                    }
                    n += 1;
                }
            }
            n
        }
        Dml::Delete { filter, .. } => {
            let before = rows.len();
            rows.retain(|row| !filter.eval(&|a: &str| *row.get(a).unwrap_or(&0)));
            (before - rows.len()) as u64
        }
    }
}

/// SUPPLIER attribute pool for randomized statements.
const SUPP_ATTRS: [(&str, usize); 5] = [
    ("s_suppkey", 24),
    ("s_nationkey", 5),
    ("s_phone_cc", 6),
    ("s_phone_rest", 36),
    ("s_acctbal", 21),
];

fn rand_value(g: &mut pimdb::util::proptest::Gen, bits: usize) -> u64 {
    // mix small values (likely to collide with data) and full-width ones
    if g.bool() {
        g.u64(0, 40.min((1u64 << bits) - 1))
    } else {
        g.u64(0, (1u64 << bits) - 1)
    }
}

fn rand_pred(g: &mut pimdb::util::proptest::Gen) -> Pred {
    let (attr, bits) = *g.pick(&SUPP_ATTRS);
    let op = *g.pick(&[CmpOp::Eq, CmpOp::Ne, CmpOp::Lt, CmpOp::Le, CmpOp::Gt, CmpOp::Ge]);
    let base = Pred::CmpImm {
        attr,
        op,
        value: rand_value(g, bits),
    };
    match g.usize(0, 3) {
        0 => base,
        1 => {
            let (a2, b2) = *g.pick(&SUPP_ATTRS);
            Pred::And(vec![
                base,
                Pred::CmpImm {
                    attr: a2,
                    op: CmpOp::Ge,
                    value: rand_value(g, b2),
                },
            ])
        }
        2 => Pred::Not(Box::new(base)),
        _ => Pred::True,
    }
}

fn rand_dml(g: &mut pimdb::util::proptest::Gen) -> Dml {
    match g.usize(0, 2) {
        0 => Dml::Insert {
            rel: RelId::Supplier,
            values: SUPP_ATTRS
                .iter()
                .map(|&(a, bits)| (a, rand_value(g, bits)))
                .collect(),
        },
        1 => {
            let (attr, bits) = *g.pick(&SUPP_ATTRS);
            Dml::Update {
                rel: RelId::Supplier,
                filter: rand_pred(g),
                sets: vec![(attr, rand_value(g, bits))],
            }
        }
        _ => Dml::Delete {
            rel: RelId::Supplier,
            filter: rand_pred(g),
        },
    }
}

fn supplier_query(filter: Pred) -> Query {
    Query {
        name: "dmlq",
        kind: QueryKind::Full,
        rels: vec![RelQuery {
            rel: RelId::Supplier,
            filter,
            group_by: vec![],
            aggregates: vec![
                Aggregate {
                    kind: AggKind::Count,
                    expr: ValExpr::One,
                    label: "n",
                },
                Aggregate {
                    kind: AggKind::Sum,
                    expr: ValExpr::Attr("s_acctbal"),
                    label: "sum_bal",
                },
                Aggregate {
                    kind: AggKind::Min,
                    expr: ValExpr::Attr("s_suppkey"),
                    label: "min_key",
                },
                Aggregate {
                    kind: AggKind::Max,
                    expr: ValExpr::Attr("s_suppkey"),
                    label: "max_key",
                },
            ],
        }],
    }
}

#[test]
fn random_dml_query_interleavings_match_baseline_and_oracle() {
    check("dml-interleave", 25, |g| {
        let cfg = SystemConfig::default();
        let seed = g.u64(0, 1 << 30);
        let db = Database::generate(0.001, seed);
        let handle = Pimdb::open(cfg.clone(), db.clone()).unwrap();
        let mut mirror = db.clone();
        let mut rows = oracle_rows(&db, RelId::Supplier);
        let mut prev_wear: Vec<u64> = Vec::new();

        for _step in 0..12 {
            if g.bool() {
                // --- a DML statement through all three implementations ---
                let dml = rand_dml(g);
                let pim = handle.execute_dml(&dml).unwrap();
                let base = baseline::apply_dml(&cfg, &mut mirror, &dml);
                let want = oracle_apply(&mut rows, RelId::Supplier, &dml);
                assert_eq!(pim.rows_affected, want, "{dml:?}");
                assert_eq!(base.rows_affected, want, "{dml:?}");
                if !matches!(dml, Dml::Insert { .. }) {
                    assert!(pim.metrics.exec_time_s > 0.0);
                    assert!(pim.metrics.cycles.total() > 0);
                }
            } else {
                // --- a query over the mutated state -----------------------
                let q = supplier_query(rand_pred(g));
                let pim = handle
                    .prepare(QuerySource::Ast(&q))
                    .unwrap()
                    .execute()
                    .unwrap();
                let base = baseline::run_query(&cfg, &mirror, &q);
                assert_eq!(
                    pim.raw_report().output,
                    base.output,
                    "engines disagree after mutation"
                );
                // scalar oracle: count + sum over live rows
                let rq = &q.rels[0];
                let want: Vec<&Row> = rows
                    .iter()
                    .filter(|r| rq.filter.eval(&|a: &str| *r.get(a).unwrap_or(&0)))
                    .collect();
                assert_eq!(pim.raw_report().output.selected[0].1, want.len() as u64);
                let sum: u128 = want.iter().map(|r| r["s_acctbal"] as u128).sum();
                let grp = &pim.raw_report().output.groups[0];
                assert_eq!(grp.values[1], ("sum_bal", sum as f64));
            }

            // liveness bookkeeping agrees everywhere
            assert_eq!(handle.live_records(RelId::Supplier), rows.len());
            assert_eq!(mirror.rel(RelId::Supplier).live_count(), rows.len());

            // per-row wear counters are monotonically nondecreasing (the
            // map may grow when INSERT materializes a fresh crossbar)
            let wear = handle.wear_counters(RelId::Supplier);
            if !wear.is_empty() {
                assert!(wear.len() >= prev_wear.len());
                for (i, w) in prev_wear.iter().enumerate() {
                    assert!(wear[i] >= *w, "wear shrank at row {i}");
                }
                prev_wear = wear;
            }
        }
    });
}

#[test]
fn deleted_rows_are_invisible_to_every_filter_shape() {
    // Two predicate classes against deleted rows:
    //  * one that *accepts* all-zero rows (zeroed deleted data would
    //    match — only the valid-bit masking excludes them);
    //  * one that *rejects* all-zero rows (the optimizer may elide the
    //    valid AND — soundness then rests on the all-zero-dead-row
    //    invariant DELETE maintains).
    // Both must report the deleted rows gone, at -O0 and -O2.
    use pimdb::query::opt::OptLevel;
    for level in [OptLevel::O0, OptLevel::O2] {
        let cfg = SystemConfig {
            opt_level: level,
            ..SystemConfig::default()
        };
        let db = Database::generate(0.01, 3);
        let total = db.rel(RelId::Supplier).records as u64;
        let handle = Pimdb::open(cfg.clone(), db).unwrap();
        let del = handle
            .execute_dml("delete from supplier where s_suppkey <= 10")
            .unwrap();
        assert_eq!(del.rows_affected, 10, "-{level}");

        // accepts-zero predicate: s_suppkey < 11 matches an all-zero row
        let r = handle
            .prepare("from supplier | filter s_suppkey < 11 | aggregate count() as n")
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.raw_report().output.groups[0].count, 0, "-{level}");

        // rejects-zero predicate: s_suppkey >= 1 (zero rows fail it)
        let r = handle
            .prepare("from supplier | filter s_suppkey >= 1 | aggregate count() as n")
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(
            r.raw_report().output.groups[0].count,
            total - 10,
            "-{level}"
        );
        assert_eq!(handle.live_records(RelId::Supplier), (total - 10) as usize);
    }
}

#[test]
fn tpch_suite_stays_bit_identical_after_mutations() {
    // acceptance criterion: after a mixed batch of DML, PIM and the
    // mutated baseline mirror agree on all 19 evaluated TPC-H queries
    let cfg = SystemConfig::default();
    let db = Database::generate(0.001, 11);
    let handle = Pimdb::open(cfg.clone(), db.clone()).unwrap();
    let mut mirror = db;

    let statements = [
        "delete from lineitem where l_quantity >= 45",
        "update lineitem set l_discount = 6 where l_shipdate < date(1993-01-01)",
        "delete from orders where o_orderstatus == \"P\"",
        "insert into lineitem (l_orderkey, l_partkey, l_suppkey, l_quantity, \
         l_extendedprice, l_discount, l_shipdate, l_commitdate, l_receiptdate) \
         values (1, 1, 1, 20, 18000.00, 0.05, date(1994-06-01), date(1994-06-10), \
         date(1994-06-20))",
        "update part set p_size = 15 where p_size == 14",
        "delete from customer where c_acctbal < 0.00",
    ];
    for src in statements {
        let dml = pimdb::query::lang::parse_dml(src).unwrap();
        let pim = handle.execute_dml(&dml).unwrap();
        let base = baseline::apply_dml(&cfg, &mut mirror, &dml);
        assert_eq!(pim.rows_affected, base.rows_affected, "{src}");
    }

    for q in tpch::all_queries() {
        let pim = handle
            .prepare(QuerySource::Ast(&q))
            .unwrap()
            .execute()
            .unwrap();
        let base = baseline::run_query(&cfg, &mirror, &q);
        assert_eq!(
            pim.raw_report().output,
            base.output,
            "{} diverged after DML",
            q.name
        );
    }
}

#[test]
fn insert_fills_least_worn_rows_and_grows_past_capacity() {
    let cfg = SystemConfig::default();
    let db = Database::generate(0.001, 5);
    let records = db.rel(RelId::Supplier).records;
    let handle = Pimdb::open(cfg, db).unwrap();

    // fill the first crossbar (capacity 1024) and two rows beyond it
    let to_insert = 1024 - records + 2;
    for i in 0..to_insert {
        let dml = Dml::Insert {
            rel: RelId::Supplier,
            values: vec![("s_suppkey", 100_000 + i as u64)],
        };
        let r = handle.execute_dml(&dml).unwrap();
        assert_eq!(r.rows_affected, 1);
        assert!(r.wear_delta > 0.0);
    }
    assert_eq!(handle.live_records(RelId::Supplier), records + to_insert);
    // the map grew by one crossbar worth of rows
    assert_eq!(handle.wear_counters(RelId::Supplier).len(), 2048);

    // every inserted key is queryable exactly once
    let r = handle
        .prepare("from supplier | filter s_suppkey >= 100_000 | aggregate count() as n")
        .unwrap()
        .execute()
        .unwrap();
    assert_eq!(r.raw_report().output.groups[0].count, to_insert as u64);
}

#[test]
fn reloading_a_mutated_host_store_matches_the_mutated_pim_copy() {
    // apply_dml keeps the all-zero-dead-row invariant on the host store,
    // so a *fresh* Pimdb opened from the mutated store must agree with
    // the incrementally mutated handle on every output
    let cfg = SystemConfig::default();
    let db = Database::generate(0.001, 9);
    let live = Pimdb::open(cfg.clone(), db.clone()).unwrap();
    let mut mirror = db;
    for src in [
        "delete from supplier where s_acctbal < 500.00",
        "update supplier set s_nationkey = 3 where s_suppkey > 50",
        "insert into supplier (s_suppkey, s_acctbal) values (7777, 123.45)",
    ] {
        let dml = pimdb::query::lang::parse_dml(src).unwrap();
        live.execute_dml(&dml).unwrap();
        baseline::apply_dml(&cfg, &mut mirror, &dml);
    }
    let reloaded = Pimdb::open(cfg, mirror).unwrap();
    // the reloaded handle's liveness matches the mutated one, both
    // before any DML (live_count fallback) and after one (from_flags
    // shadowing the holes in the mutated image)
    assert_eq!(
        reloaded.live_records(RelId::Supplier),
        live.live_records(RelId::Supplier)
    );
    reloaded
        .execute_dml("insert into supplier (s_suppkey) values (8888)")
        .unwrap();
    live.execute_dml("insert into supplier (s_suppkey) values (8888)")
        .unwrap();
    assert_eq!(
        reloaded.live_records(RelId::Supplier),
        live.live_records(RelId::Supplier)
    );
    for src in [
        "from supplier | filter true | aggregate count() as n, sum(s_acctbal) as s",
        "from supplier | filter s_nationkey == 3 | aggregate count() as n",
        "from supplier | filter s_suppkey == 7777 | aggregate count() as n",
    ] {
        let a = live.prepare(src).unwrap().execute().unwrap();
        let b = reloaded.prepare(src).unwrap().execute().unwrap();
        assert_eq!(a.raw_report().output, b.raw_report().output, "{src}");
    }
}
