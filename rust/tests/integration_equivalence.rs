//! Cross-engine functional equivalence: for every evaluated query, the
//! PIMDB bulk-bitwise execution must produce exactly the results of the
//! host column-store baseline (which is itself oracle-checked in unit
//! tests). This is the repo's core correctness gate.

use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::exec::{baseline, pimdb as engine};
use pimdb::query::tpch;

#[test]
fn all_queries_pimdb_equals_baseline() {
    let mut cfg = SystemConfig::default();
    cfg.sim_sf = 0.002;
    let db = Database::generate(cfg.sim_sf, 1234);
    for q in tpch::all_queries() {
        let pim = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native)
            .unwrap_or_else(|e| panic!("{}: {e}", q.name));
        let base = baseline::run_query(&cfg, &db, &q);
        assert_eq!(pim.output, base.output, "{} outputs differ", q.name);
    }
}

#[test]
fn equivalence_holds_across_seeds_and_scales() {
    for (sf, seed) in [(0.001, 7), (0.003, 99)] {
        let mut cfg = SystemConfig::default();
        cfg.sim_sf = sf;
        let db = Database::generate(sf, seed);
        for name in ["Q1", "Q6", "Q12", "Q19", "Q22_sub"] {
            let q = tpch::query(name).unwrap();
            let pim = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native).unwrap();
            let base = baseline::run_query(&cfg, &db, &q);
            assert_eq!(pim.output, base.output, "{name} sf={sf} seed={seed}");
        }
    }
}

/// PJRT backend equals native on a mixed query sample (vacuous skip when
/// artifacts are absent).
#[test]
fn pjrt_engine_equals_native_on_queries() {
    if !pimdb::runtime::runtime_available() {
        eprintln!("skipping: PJRT runtime/artifacts unavailable");
        return;
    }
    let mut cfg = SystemConfig::default();
    cfg.sim_sf = 0.001;
    let db = Database::generate(cfg.sim_sf, 5);
    for name in ["Q6", "Q12", "Q22_sub", "Q4"] {
        let q = tpch::query(name).unwrap();
        let native = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native).unwrap();
        let pjrt = engine::run_query(&cfg, &db, &q, engine::EngineKind::Pjrt).unwrap();
        assert_eq!(native.output, pjrt.output, "{name}");
    }
}
