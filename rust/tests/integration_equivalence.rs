//! Cross-engine functional equivalence: for every evaluated query, the
//! PIMDB bulk-bitwise execution must produce exactly the results of the
//! host column-store baseline (which is itself oracle-checked in unit
//! tests). This is the repo's core correctness gate.

use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::exec::metrics::QueryMetrics;
use pimdb::exec::{baseline, pimdb as engine};
use pimdb::query::tpch;

/// The simulated metrics must not depend on the host `parallelism` knob:
/// every float compares by bit pattern, not tolerance.
fn assert_metrics_bit_identical(a: &QueryMetrics, b: &QueryMetrics, ctx: &str) {
    assert_eq!(a.cycles, b.cycles, "{ctx}: cycle counts");
    assert_eq!(a.inter_cells, b.inter_cells, "{ctx}: inter cells");
    assert_eq!(a.llc_misses, b.llc_misses, "{ctx}: llc misses");
    assert_eq!(a.pim_energy, b.pim_energy, "{ctx}: pim energy ledger");
    for (x, y, what) in [
        (a.exec_time_s, b.exec_time_s, "exec_time_s"),
        (a.pim_time_s, b.pim_time_s, "pim_time_s"),
        (a.read_time_s, b.read_time_s, "read_time_s"),
        (a.other_time_s, b.other_time_s, "other_time_s"),
        (a.host_energy_pj, b.host_energy_pj, "host_energy_pj"),
        (a.dram_energy_pj, b.dram_energy_pj, "dram_energy_pj"),
        (a.peak_chip_w, b.peak_chip_w, "peak_chip_w"),
        (a.avg_chip_w, b.avg_chip_w, "avg_chip_w"),
        (a.theoretical_chip_w, b.theoretical_chip_w, "theoretical_chip_w"),
        (a.ops_per_cell, b.ops_per_cell, "ops_per_cell"),
        (
            a.required_endurance_10yr,
            b.required_endurance_10yr,
            "required_endurance_10yr",
        ),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {what}");
    }
    for i in 0..5 {
        assert_eq!(
            a.endurance_breakdown[i].to_bits(),
            b.endurance_breakdown[i].to_bits(),
            "{ctx}: endurance_breakdown[{i}]"
        );
    }
}

#[test]
fn all_queries_pimdb_equals_baseline() {
    let mut cfg = SystemConfig::default();
    cfg.sim_sf = 0.002;
    let db = Database::generate(cfg.sim_sf, 1234);
    for q in tpch::all_queries() {
        let pim = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native)
            .unwrap_or_else(|e| panic!("{}: {e}", q.name));
        let base = baseline::run_query(&cfg, &db, &q);
        assert_eq!(pim.output, base.output, "{} outputs differ", q.name);
    }
}

#[test]
fn equivalence_holds_across_seeds_and_scales() {
    for (sf, seed) in [(0.001, 7), (0.003, 99)] {
        let mut cfg = SystemConfig::default();
        cfg.sim_sf = sf;
        let db = Database::generate(sf, seed);
        for name in ["Q1", "Q6", "Q12", "Q19", "Q22_sub"] {
            let q = tpch::query(name).unwrap();
            let pim = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native).unwrap();
            let base = baseline::run_query(&cfg, &db, &q);
            assert_eq!(pim.output, base.output, "{name} sf={sf} seed={seed}");
        }
    }
}

/// Every TPC-H query must be bit-identical across serial native (1
/// worker/shard), parallel native with 2 workers (4 shards) and 8 workers
/// (16 shards) — outputs *and* cycle/energy/endurance/timing totals — and
/// equal to the baseline's functional output.
#[test]
fn all_queries_bit_identical_across_parallelism() {
    let mk_cfg = |p: usize| SystemConfig {
        sim_sf: 0.002,
        parallelism: p,
        ..SystemConfig::default()
    };
    let (cfg1, cfg2, cfg8) = (mk_cfg(1), mk_cfg(2), mk_cfg(8));
    let db = Database::generate(0.002, 1234);
    let mut s1 = engine::PimSession::new(&cfg1, &db).unwrap();
    let mut s2 = engine::PimSession::new(&cfg2, &db).unwrap();
    let mut s8 = engine::PimSession::new(&cfg8, &db).unwrap();
    for q in tpch::all_queries() {
        let serial = s1
            .run_query(&q, engine::EngineKind::Native)
            .unwrap_or_else(|e| panic!("{}: {e}", q.name));
        let base = baseline::run_query(&cfg1, &db, &q);
        assert_eq!(serial.output, base.output, "{} serial vs baseline", q.name);
        let par2 = s2.run_query(&q, engine::EngineKind::Native).unwrap();
        let par8 = s8.run_query(&q, engine::EngineKind::Native).unwrap();
        for (r, label) in [(&par2, "2 workers"), (&par8, "8 workers")] {
            assert_eq!(r.output, serial.output, "{} {label}: outputs", q.name);
            assert_metrics_bit_identical(
                &r.metrics,
                &serial.metrics,
                &format!("{} {label}", q.name),
            );
        }
    }
}

/// The batched entry point must equal one-by-one execution, including
/// when consecutive queries share a relation (forcing wave splits).
#[test]
fn batched_run_queries_matches_individual_runs() {
    let cfg = SystemConfig {
        sim_sf: 0.002,
        parallelism: 4,
        ..SystemConfig::default()
    };
    let db = Database::generate(cfg.sim_sf, 1234);
    let queries = tpch::all_queries();
    let mut batch = engine::PimSession::new(&cfg, &db).unwrap();
    let reports = batch
        .run_queries(&queries, engine::EngineKind::Native)
        .unwrap();
    assert_eq!(reports.len(), queries.len());
    let mut single = engine::PimSession::new(&cfg, &db).unwrap();
    for (q, got) in queries.iter().zip(&reports) {
        assert_eq!(got.query, q.name, "report order must match input order");
        let want = single.run_query(q, engine::EngineKind::Native).unwrap();
        assert_eq!(want.output, got.output, "{} batched output", q.name);
        assert_metrics_bit_identical(&want.metrics, &got.metrics, &format!("{} batched", q.name));
    }
}

/// PJRT backend equals native on a mixed query sample (vacuous skip when
/// artifacts are absent).
#[test]
fn pjrt_engine_equals_native_on_queries() {
    if !pimdb::runtime::runtime_available() {
        eprintln!("skipping: PJRT runtime/artifacts unavailable");
        return;
    }
    let mut cfg = SystemConfig::default();
    cfg.sim_sf = 0.001;
    let db = Database::generate(cfg.sim_sf, 5);
    for name in ["Q6", "Q12", "Q22_sub", "Q4"] {
        let q = tpch::query(name).unwrap();
        let native = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native).unwrap();
        let pjrt = engine::run_query(&cfg, &db, &q, engine::EngineKind::Pjrt).unwrap();
        assert_eq!(native.output, pjrt.output, "{name}");
    }
}
