//! Round-trip fidelity of the PQL text frontend: every evaluated TPC-H
//! query, re-expressed as a `tests/pql/*.pql` fixture, must lower to an
//! AST node-for-node equal to the hardcoded definition in
//! `pimdb::query::tpch`. Any drift — a predicate shape, a dictionary id,
//! a date encoding, an aggregate label — fails here with the query name.

use pimdb::query::ast::QueryKind;
use pimdb::query::lang::parse_program;
use pimdb::query::tpch;

const FIXTURES: &[(&str, &str)] = &[
    ("Q1", include_str!("pql/q1.pql")),
    ("Q2", include_str!("pql/q2.pql")),
    ("Q3", include_str!("pql/q3.pql")),
    ("Q4", include_str!("pql/q4.pql")),
    ("Q5", include_str!("pql/q5.pql")),
    ("Q6", include_str!("pql/q6.pql")),
    ("Q7", include_str!("pql/q7.pql")),
    ("Q8", include_str!("pql/q8.pql")),
    ("Q10", include_str!("pql/q10.pql")),
    ("Q11", include_str!("pql/q11.pql")),
    ("Q12", include_str!("pql/q12.pql")),
    ("Q14", include_str!("pql/q14.pql")),
    ("Q15", include_str!("pql/q15.pql")),
    ("Q16", include_str!("pql/q16.pql")),
    ("Q17", include_str!("pql/q17.pql")),
    ("Q19", include_str!("pql/q19.pql")),
    ("Q20", include_str!("pql/q20.pql")),
    ("Q21", include_str!("pql/q21.pql")),
    ("Q22_sub", include_str!("pql/q22_sub.pql")),
];

#[test]
fn fixtures_cover_every_evaluated_query() {
    let mut want: Vec<&str> = tpch::all_queries().iter().map(|q| q.name).collect();
    let mut have: Vec<&str> = FIXTURES.iter().map(|&(n, _)| n).collect();
    want.sort_unstable();
    have.sort_unstable();
    assert_eq!(want, have, "fixture set drifted from tpch::all_queries()");
}

#[test]
fn pql_fixtures_lower_to_the_hardcoded_asts() {
    for &(name, src) in FIXTURES {
        let parsed = parse_program(src)
            .unwrap_or_else(|e| panic!("{name}: {}", e.render(src)));
        assert_eq!(parsed.len(), 1, "{name}: expected one query block");
        let want = tpch::query(name).expect("fixture name is a tpch query");
        assert_eq!(
            parsed[0], want,
            "{name}: parsed .pql fixture differs from the hardcoded AST"
        );
    }
}

#[test]
fn fixture_kinds_match_table2() {
    for &(name, src) in FIXTURES {
        let q = &parse_program(src).unwrap()[0];
        let want = if matches!(name, "Q1" | "Q6" | "Q22_sub") {
            QueryKind::Full
        } else {
            QueryKind::FilterOnly
        };
        assert_eq!(q.kind, want, "{name}");
    }
}
