//! Batch execution battery: `Pimdb::execute_batch` pinned bit-for-bit
//! against serial `Prepared::execute`.
//!
//! The multi-query fusion pass is a simulator shortcut — the fused scan
//! shares the work of identical filter subexpressions across the batch,
//! it must not change what any member computes or is charged. So every
//! output, every Table 5/6 metric and the shared-scan counter story must
//! be identical to executing the members one at a time, at every
//! shard-pool parallelism; and under concurrent DML every member of one
//! batch must observe the same committed snapshot per relation.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};

use pimdb::api::{Pimdb, Prepared, QueryResult, QuerySource};
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::exec::metrics::QueryMetrics;
use pimdb::query::tpch;

fn db() -> Database {
    Database::generate(0.001, 11)
}

fn handle_with(parallelism: usize) -> Pimdb {
    let cfg = SystemConfig {
        parallelism,
        ..SystemConfig::default()
    };
    Pimdb::open(cfg, db()).unwrap()
}

/// Ad-hoc PQL members riding along with the 19 TPC-H queries: filter
/// prefixes repeat within the set (cross-member sharing) and span two
/// relations (per-relation fusion grouping).
const PQL: &[&str] = &[
    "from supplier | filter s_suppkey < 50 | aggregate count() as n",
    "from supplier | filter s_suppkey < 50 | aggregate sum(s_acctbal) as s",
    "from supplier | filter s_acctbal > 100.00 | aggregate count() as n",
    "from part | filter p_size < 25 | aggregate count() as n",
    "from part | filter p_size < 25 | aggregate sum(p_retailprice) as v",
];

/// Every simulated metric must be bit-identical (floats compare by bit
/// pattern, not tolerance) — both sides run through `Pimdb`, so even
/// `plan_cache` must agree.
fn assert_metrics_identical(am: &QueryMetrics, bm: &QueryMetrics, ctx: &str) {
    assert_eq!(am.cycles, bm.cycles, "{ctx}: cycle counts");
    assert_eq!(am.inter_cells, bm.inter_cells, "{ctx}: inter cells");
    assert_eq!(am.opt, bm.opt, "{ctx}: optimizer summary");
    assert_eq!(am.llc_misses, bm.llc_misses, "{ctx}: llc misses");
    assert_eq!(am.pim_energy, bm.pim_energy, "{ctx}: pim energy ledger");
    assert_eq!(am.plan_cache, bm.plan_cache, "{ctx}: plan cache counters");
    for (x, y, what) in [
        (am.exec_time_s, bm.exec_time_s, "exec_time_s"),
        (am.pim_time_s, bm.pim_time_s, "pim_time_s"),
        (am.read_time_s, bm.read_time_s, "read_time_s"),
        (am.other_time_s, bm.other_time_s, "other_time_s"),
        (am.host_energy_pj, bm.host_energy_pj, "host_energy_pj"),
        (am.dram_energy_pj, bm.dram_energy_pj, "dram_energy_pj"),
        (am.peak_chip_w, bm.peak_chip_w, "peak_chip_w"),
        (am.avg_chip_w, bm.avg_chip_w, "avg_chip_w"),
        (
            am.theoretical_chip_w,
            bm.theoretical_chip_w,
            "theoretical_chip_w",
        ),
        (am.ops_per_cell, bm.ops_per_cell, "ops_per_cell"),
        (
            am.required_endurance_10yr,
            bm.required_endurance_10yr,
            "required_endurance_10yr",
        ),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {what}");
    }
    for i in 0..5 {
        assert_eq!(
            am.endurance_breakdown[i].to_bits(),
            bm.endurance_breakdown[i].to_bits(),
            "{ctx}: endurance_breakdown[{i}]"
        );
    }
}

/// The full 19-query TPC-H sweep plus the PQL set, one `execute_batch`
/// call vs the member-by-member serial run on a twin handle.
fn batch_matches_serial(parallelism: usize) {
    let serial = handle_with(parallelism);
    let batched = handle_with(parallelism);

    let queries = tpch::all_queries();
    let mut sp: Vec<Prepared<'_>> = Vec::new();
    let mut bp: Vec<Prepared<'_>> = Vec::new();
    for q in &queries {
        sp.push(serial.prepare(QuerySource::Ast(q)).unwrap());
        bp.push(batched.prepare(QuerySource::Ast(q)).unwrap());
    }
    for src in PQL {
        sp.push(serial.prepare(*src).unwrap());
        bp.push(batched.prepare(*src).unwrap());
    }

    let want: Vec<QueryResult> = sp.iter().map(|p| p.execute().unwrap()).collect();
    let refs: Vec<&Prepared<'_>> = bp.iter().collect();
    let got = batched.execute_batch(&refs).unwrap();
    assert_eq!(want.len(), got.len());
    for (w, g) in want.iter().zip(&got) {
        let ctx = w.query_name();
        assert_eq!(w.query_name(), g.query_name(), "{ctx}: name");
        assert_eq!(
            w.raw_report().output,
            g.raw_report().output,
            "{ctx}: functional output"
        );
        assert_metrics_identical(w.metrics(), g.metrics(), ctx);
    }
    // the batch tells the identical shared-scan counter story, and the
    // sweep actually exercised cross-member sharing
    assert_eq!(
        serial.shared_scan_counters(),
        batched.shared_scan_counters(),
        "shared-scan counters diverged from the serial twin"
    );
    assert!(
        batched.shared_scan_counters().hits > 0,
        "expected shared prefixes in the sweep"
    );

    // a second batch over a warm cache replays every shareable mask and
    // still matches the serial twin's re-run
    let want2: Vec<QueryResult> = sp.iter().map(|p| p.execute().unwrap()).collect();
    let got2 = batched.execute_batch(&refs).unwrap();
    for (w, g) in want2.iter().zip(&got2) {
        assert_eq!(w.raw_report().output, g.raw_report().output, "warm re-run");
    }
    assert_eq!(
        serial.shared_scan_counters(),
        batched.shared_scan_counters(),
        "warm-cache counters diverged"
    );
}

#[test]
fn batch_matches_serial_inline_pool() {
    batch_matches_serial(1);
}

#[test]
fn batch_matches_serial_two_workers() {
    batch_matches_serial(2);
}

#[test]
fn batch_matches_serial_eight_workers() {
    batch_matches_serial(8);
}

/// Every member of one batch pins the same snapshot per relation: under
/// a concurrent writer, a probe repeated within one batch always agrees
/// with itself, and the batch's (sum, count) pair is exactly one
/// committed oracle state — never a torn mixture — observed in monotone
/// commit order.
#[test]
fn batch_members_share_one_snapshot_under_concurrent_dml() {
    let sum_probe = "from supplier | filter s_suppkey >= 1 | aggregate sum(s_acctbal) as s";
    let count_probe = "from supplier | filter s_suppkey >= 1 | aggregate count() as n";
    let keys: Vec<u64> = (1..=8).collect();
    let delete_stmt = |k: u64| format!("delete from supplier where s_suppkey == {k}");

    // oracle chain of (sum, count) outputs after each committed delete
    let oracle = handle_with(2);
    let chain_at = |h: &Pimdb| {
        (
            h.prepare(sum_probe)
                .unwrap()
                .execute()
                .unwrap()
                .raw_report()
                .output
                .clone(),
            h.prepare(count_probe)
                .unwrap()
                .execute()
                .unwrap()
                .raw_report()
                .output
                .clone(),
        )
    };
    let mut chain = vec![chain_at(&oracle)];
    for &k in &keys {
        let r = oracle.execute_dml(delete_stmt(k).as_str()).unwrap();
        assert_eq!(r.rows_affected, 1, "oracle delete of key {k}");
        chain.push(chain_at(&oracle));
    }

    let handle = Arc::new(handle_with(2));
    let p_sum = handle.prepare(sum_probe).unwrap();
    let p_count = handle.prepare(count_probe).unwrap();
    let done = AtomicBool::new(false);
    let start = Barrier::new(2);
    std::thread::scope(|s| {
        let reader = s.spawn(|| {
            start.wait();
            let mut last = 0usize;
            loop {
                let stop = done.load(Ordering::Acquire);
                let batch = [&p_sum, &p_count, &p_sum];
                let r = handle.execute_batch(&batch).unwrap();
                // one snapshot per relation per batch: the repeated
                // member agrees with itself...
                assert_eq!(
                    r[0].raw_report().output,
                    r[2].raw_report().output,
                    "repeated member diverged within one batch"
                );
                // ...and the pair is exactly one committed chain state
                let state = (
                    r[0].raw_report().output.clone(),
                    r[1].raw_report().output.clone(),
                );
                let idx = chain
                    .iter()
                    .position(|c| *c == state)
                    .expect("batch observed a torn or off-chain state");
                assert!(idx >= last, "chain ran backwards: {last} -> {idx}");
                last = idx;
                if stop {
                    break;
                }
            }
        });
        start.wait();
        for &k in &keys {
            let r = handle.execute_dml(delete_stmt(k).as_str()).unwrap();
            assert_eq!(r.rows_affected, 1, "stress delete of key {k}");
        }
        done.store(true, Ordering::Release);
        reader.join().unwrap();
    });

    // the final batch lands on the end of the chain
    let r = handle.execute_batch(&[&p_sum, &p_count]).unwrap();
    let state = (
        r[0].raw_report().output.clone(),
        r[1].raw_report().output.clone(),
    );
    assert_eq!(state, chain[keys.len()]);
}
