//! Optimizer correctness gate: `-O2` (and `-O1`) must be **functionally
//! bit-identical** to `-O0` — the compiler's naive streams — for every
//! evaluated TPC-H query, every PQL fixture, and ad-hoc text queries,
//! while total PIM compute cycles drop on a majority of the 19 queries
//! and `peak_inter_cells` never increases. This is the differential
//! contract from the optimizer's acceptance criteria; the per-pass unit
//! and property tests live next to the passes in `src/query/opt/`.

use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::exec::baseline;
use pimdb::exec::metrics::RunReport;
use pimdb::exec::pimdb::{EngineKind, PimSession};
use pimdb::query::ast::Query;
use pimdb::query::lang::parse_program;
use pimdb::query::opt::OptLevel;
use pimdb::query::tpch;

fn cfg_at(level: OptLevel) -> SystemConfig {
    SystemConfig {
        sim_sf: 0.002,
        opt_level: level,
        ..SystemConfig::default()
    }
}

fn run_at(db: &Database, q: &Query, level: OptLevel) -> RunReport {
    PimSession::new(&cfg_at(level), db)
        .unwrap()
        .run_query(q, EngineKind::Native)
        .unwrap()
}

#[test]
fn o2_bit_identical_to_o0_on_all_19_queries_with_cycle_wins() {
    let db = Database::generate(0.002, 42);
    let mut improved = 0usize;
    let queries = tpch::all_queries();
    // one resident session per level: the database copy loads once
    let (c0, c1, c2) = (cfg_at(OptLevel::O0), cfg_at(OptLevel::O1), cfg_at(OptLevel::O2));
    let mut s0 = PimSession::new(&c0, &db).unwrap();
    let mut s1 = PimSession::new(&c1, &db).unwrap();
    let mut s2 = PimSession::new(&c2, &db).unwrap();
    for q in &queries {
        let a = s0.run_query(q, EngineKind::Native).unwrap();
        let b = s1.run_query(q, EngineKind::Native).unwrap();
        let c = s2.run_query(q, EngineKind::Native).unwrap();
        assert_eq!(a.output, b.output, "{}: -O1 output drifted", q.name);
        assert_eq!(a.output, c.output, "{}: -O2 output drifted", q.name);
        assert!(
            b.metrics.cycles.total() <= a.metrics.cycles.total(),
            "{}: -O1 cycles grew",
            q.name
        );
        assert!(
            c.metrics.cycles.total() <= b.metrics.cycles.total(),
            "{}: -O2 cycles above -O1",
            q.name
        );
        assert!(
            c.metrics.inter_cells <= a.metrics.inter_cells,
            "{}: inter cells grew {} -> {}",
            q.name,
            a.metrics.inter_cells,
            c.metrics.inter_cells
        );
        if c.metrics.cycles.total() < a.metrics.cycles.total() {
            improved += 1;
        }
    }
    assert!(
        improved >= 10,
        "-O2 reduced cycles on only {improved}/19 queries"
    );
}

#[test]
fn o2_matches_baseline_on_every_query() {
    // the baseline never sees the optimizer: agreement proves -O2 results
    // against an independent executor, not just against -O0
    let cfg = SystemConfig {
        sim_sf: 0.002,
        ..SystemConfig::default() // -O2 default
    };
    let db = Database::generate(0.002, 42);
    let mut session = PimSession::new(&cfg, &db).unwrap();
    for q in tpch::all_queries() {
        let pim = session.run_query(&q, EngineKind::Native).unwrap();
        let base = baseline::run_query(&cfg, &db, &q);
        assert_eq!(pim.output, base.output, "{}", q.name);
    }
}

#[test]
fn o2_bit_identical_on_pql_fixtures() {
    // the fixtures lower through the text frontend (tests/pql_fixtures.rs
    // proves AST equality); here they must execute identically at -O0/-O2
    let fixtures: &[&str] = &[
        include_str!("pql/q1.pql"),
        include_str!("pql/q6.pql"),
        include_str!("pql/q12.pql"),
        include_str!("pql/q16.pql"),
        include_str!("pql/q19.pql"),
        include_str!("pql/q22_sub.pql"),
    ];
    let db = Database::generate(0.002, 42);
    for src in fixtures {
        let q = &parse_program(src).unwrap()[0];
        let a = run_at(&db, q, OptLevel::O0);
        let c = run_at(&db, q, OptLevel::O2);
        assert_eq!(a.output, c.output, "{}", q.name);
        assert!(c.metrics.cycles.total() <= a.metrics.cycles.total());
    }
}

#[test]
fn o2_bit_identical_on_adhoc_text_queries() {
    // never-hardcoded ad-hoc shapes: grouped aggregates, IN-sets, nested
    // boolean structure — the same paths `pimdb run --sql` drives
    let sources = [
        "from supplier
         | filter s_acctbal > 912.00
             and (s_nationkey in region(\"AFRICA\") or s_phone_cc in (20, 25))
             and not s_suppkey < 3
         | aggregate count() as n, sum(s_acctbal) as s, avg(s_acctbal) as a",
        "from customer
         | filter c_acctbal > 0.00
         | group by c_mktsegment
         | aggregate count() as customers, avg(c_acctbal) as avg_bal",
        "from lineitem
         | filter l_shipmode in (\"MAIL\", \"SHIP\", \"AIR\") and l_quantity < 30
         | aggregate min(l_extendedprice) as lo, max(l_extendedprice) as hi",
    ];
    let db = Database::generate(0.002, 7);
    for src in sources {
        let q = &parse_program(src).unwrap()[0];
        let a = run_at(&db, q, OptLevel::O0);
        let c = run_at(&db, q, OptLevel::O2);
        assert_eq!(a.output, c.output, "adhoc: {src}");
        assert!(c.metrics.cycles.total() <= a.metrics.cycles.total());
    }
}
