//! Paper-shape integration tests: the qualitative findings of the
//! evaluation (§6) must hold in our reproduction — who wins, by roughly
//! what factor, and where the crossovers fall (DESIGN.md §3 scale note).

use pimdb::config::SystemConfig;
use pimdb::exec::pimdb::EngineKind;
use pimdb::query::ast::QueryKind;
use pimdb::report::Experiments;

fn experiments() -> &'static Experiments {
    use std::sync::OnceLock;
    static EXPS: OnceLock<Experiments> = OnceLock::new();
    EXPS.get_or_init(|| {
        let mut cfg = SystemConfig::default();
        cfg.sim_sf = 0.004;
        Experiments::run(&cfg, EngineKind::Native).unwrap()
    })
}

#[test]
fn fig8_full_queries_beat_filter_only() {
    let e = experiments();
    let max_filter = e
        .filter_only()
        .map(|p| p.speedup())
        .fold(0.0f64, f64::max);
    for p in e.full() {
        if p.query.name == "Q22_sub" {
            continue; // PIM-cycle-bound small relation; see EXPERIMENTS.md
        }
        assert!(
            p.speedup() > max_filter,
            "{} ({:.1}x) should beat best filter-only ({max_filter:.1}x)",
            p.query.name,
            p.speedup()
        );
    }
}

#[test]
fn fig8_filter_only_band_and_q11_minimum() {
    let e = experiments();
    let mut speedups: Vec<(&str, f64)> = e
        .filter_only()
        .map(|p| (p.query.name, p.speedup()))
        .collect();
    // paper band 1.6-18x with Q11 at ~0.82x: allow a loose band
    for &(name, s) in &speedups {
        assert!(
            (0.5..60.0).contains(&s),
            "{name} speedup {s:.2} outside sanity band"
        );
    }
    speedups.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    assert_eq!(speedups[0].0, "Q11", "Q11 must be the slowest case");
    assert!(speedups[0].1 < 3.0);
}

#[test]
fn fig8_llc_miss_reduction_everywhere() {
    for p in &experiments().pairs {
        assert!(
            p.llc_reduction() > 1.0,
            "{} must reduce LLC misses",
            p.query.name
        );
    }
    // aggregation reduces reads by ~3 orders of magnitude (paper: >99% of
    // reads eliminated for some queries)
    let q6 = experiments()
        .pairs
        .iter()
        .find(|p| p.query.name == "Q6")
        .unwrap();
    assert!(q6.llc_reduction() > 100.0);
}

#[test]
fn fig9_read_time_dominates_large_filter_only_queries() {
    let e = experiments();
    for p in e.filter_only() {
        let m = &p.pim.metrics;
        let rels: Vec<_> = p.query.rels.iter().map(|r| r.rel.name()).collect();
        // the paper's >99% read share holds for queries on LINEITEM/ORDERS
        if rels.contains(&"LINEITEM") || rels.contains(&"ORDERS") {
            let tot = m.pim_time_s + m.read_time_s + m.other_time_s;
            assert!(
                m.read_time_s / tot > 0.8,
                "{}: read share {:.2}",
                p.query.name,
                m.read_time_s / tot
            );
        }
    }
}

#[test]
fn fig9_full_queries_have_moderate_read_share() {
    let e = experiments();
    for p in e.full() {
        let m = &p.pim.metrics;
        let tot = m.pim_time_s + m.read_time_s + m.other_time_s;
        let read = m.read_time_s / tot;
        match p.query.name {
            // paper: 70% (Q1), 55% (Q6) — read is the bottleneck but
            // moderately; Q22_sub's read is NOT the bottleneck
            "Q1" | "Q6" => assert!(
                (0.3..0.9).contains(&read),
                "{}: read share {read:.2}",
                p.query.name
            ),
            "Q22_sub" => assert!(read < 0.5, "Q22_sub read share {read:.2}"),
            _ => {}
        }
    }
}

#[test]
fn fig11_12_13_energy_structure() {
    let e = experiments();
    for p in &e.pairs {
        let m = &p.pim.metrics;
        match p.query.kind {
            QueryKind::FilterOnly => {
                // paper Fig 12: DRAM standby dominates PIMDB energy for
                // filter-only queries on the big relations
                if p.query.rels.iter().any(|r| r.rel.name() == "LINEITEM") {
                    assert!(
                        m.dram_energy_pj + m.host_energy_pj > 0.2 * m.total_energy_pj(),
                        "{}",
                        p.query.name
                    );
                }
            }
            QueryKind::Full => {
                // paper Fig 13: >99% of PIM-module energy is stateful logic
                let pim = &m.pim_energy;
                assert!(
                    pim.logic_pj / pim.total_pj() > 0.9,
                    "{}: logic share {:.3}",
                    p.query.name,
                    pim.logic_pj / pim.total_pj()
                );
            }
        }
    }
}

#[test]
fn fig14_power_hierarchy() {
    let e = experiments();
    let all_xbars = pimdb::pim::power::theoretical_peak_all_xbars_chip_w(&e.cfg);
    assert!((all_xbars - 730.0).abs() / 730.0 < 0.05);
    for p in &e.pairs {
        let m = &p.pim.metrics;
        // measured avg <= measured peak <= ~theoretical bound x margin
        assert!(m.avg_chip_w <= m.peak_chip_w + 1e-9, "{}", p.query.name);
        assert!(
            m.peak_chip_w <= all_xbars * 1.05,
            "{}: peak {} exceeds physical bound",
            p.query.name,
            m.peak_chip_w
        );
        assert!(m.theoretical_chip_w <= all_xbars * 1.0001);
    }
}

#[test]
fn fig15_endurance_q22_is_the_outlier() {
    let e = experiments();
    let q22 = e
        .pairs
        .iter()
        .find(|p| p.query.name == "Q22_sub")
        .unwrap();
    for p in &e.pairs {
        if p.query.name != "Q22_sub" {
            assert!(
                p.pim.metrics.required_endurance_10yr
                    <= q22.pim.metrics.required_endurance_10yr * 1.01,
                "{} wears faster than Q22_sub",
                p.query.name
            );
        }
    }
}

#[test]
fn table6_filter_dominates_filter_only_endurance() {
    let e = experiments();
    for p in e.filter_only() {
        let b = p.pim.metrics.endurance_breakdown;
        // paper Table 6: filter ops dominate (col-transform moves few
        // bits per row); exceptions are tiny-filter queries like Q11/Q17
        if !["Q11", "Q17", "Q3"].contains(&p.query.name) {
            assert!(
                b[0] > b[2],
                "{}: filter {:.2} vs coltrans {:.2}",
                p.query.name,
                b[0],
                b[2]
            );
        }
    }
    for p in e.full() {
        let b = p.pim.metrics.endurance_breakdown;
        // paper: reduce column-wise ops dominate full-query wear
        assert!(
            b[3] > b[4],
            "{}: agg-col {:.2} vs agg-row {:.2}",
            p.query.name,
            b[3],
            b[4]
        );
    }
}

#[test]
fn energy_savings_in_loose_paper_band() {
    let e = experiments();
    for p in &e.pairs {
        let s = p.energy_reduction();
        assert!(
            (0.2..100.0).contains(&s),
            "{}: energy reduction {s:.2} out of band",
            p.query.name
        );
    }
}
